"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode on CPU; same calls compile to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (64, 64, 64, 64, 64, 64),
    (128, 256, 96, 64, 128, 32),
    (100, 130, 50, 32, 64, 32),      # ragged -> padding path
    (256, 512, 256, 128, 256, 128),
])
def test_vwr_matmul(dtype, m, k, n, bm, bk, bn):
    k1, k2 = jax.random.split(KEY)
    x = _rand(k1, (m, k), dtype)
    w = _rand(k2, (k, n), dtype)
    out = ops.vwr_matmul(x, w, bm=bm, bk=bk, bn=bn)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,c,f,kh,kw,bh,bf", [
    (1, 9, 9, 8, 8, 3, 3, 2, 8),
    (2, 13, 11, 7, 5, 3, 3, 4, 4),
    (1, 8, 8, 4, 16, 1, 1, 4, 16),   # 1x1 conv
    (2, 12, 10, 3, 9, 5, 5, 4, 4),
])
def test_vwr_conv2d(dtype, n, h, w, c, f, kh, kw, bh, bf):
    k1, k2 = jax.random.split(KEY)
    x = _rand(k1, (n, h, w, c), dtype)
    wts = _rand(k2, (kh, kw, c, f), dtype)
    out = ops.vwr_conv2d(x, wts, bh=bh, bf=bf)
    want = ref.conv2d_ref(x, wts)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,c,k,bh", [
    (1, 10, 10, 8, 3, 4),
    (2, 12, 9, 16, 3, 2),
    (1, 9, 9, 4, 5, 5),
])
def test_vwr_depthwise(dtype, n, h, w, c, k, bh):
    k1, k2 = jax.random.split(KEY)
    x = _rand(k1, (n, h, w, c), dtype)
    wts = _rand(k2, (k, k, c), dtype)
    out = ops.vwr_depthwise(x, wts, bh=bh)
    want = ref.depthwise_ref(x, wts)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,act,bias,res", [
    (64, 64, 64, "relu", True, False),
    (100, 130, 50, "gelu", True, True),      # ragged + full epilogue
    (128, 64, 96, "silu", False, True),
    (64, 128, 64, None, True, True),         # bias+residual only
])
def test_vwr_matmul_fused_epilogue(dtype, m, k, n, act, bias, res):
    """Fused bias/activation/residual == the unfused two-pass
    composition (the final-K store applies the epilogue in fp32)."""
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = _rand(k1, (m, k), dtype)
    w = _rand(k2, (k, n), dtype)
    b = _rand(k3, (n,), dtype) if bias else None
    r = _rand(k4, (m, n), dtype) if res else None
    out = ops.vwr_matmul(x, w, b, r, activation=act, bm=32, bk=64, bn=32)
    want = ref.matmul_ref(x, w).astype(jnp.float32)
    if b is not None:
        want = want + b.astype(jnp.float32)
    if act is not None:
        fn = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
              "silu": jax.nn.silu}[act]
        want = fn(want)
    if r is not None:
        want = want + r.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want.astype(dtype), np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (64, 64, 64, 64, 64, 64),
    (100, 130, 50, 32, 64, 32),      # ragged -> padding path
    (128, 256, 96, 64, 128, 32),
])
def test_vwr_swiglu_fused_dual_matmul(dtype, m, k, n, bm, bk, bn):
    """The dual-matmul fused swiglu == silu(x@wg) * (x@wi) composed
    from two plain matmuls (one staged x block, the gate product on
    the fp32 accumulators in the final-K store)."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = _rand(k1, (m, k), dtype)
    wg = _rand(k2, (k, n), dtype)
    wi = _rand(k3, (k, n), dtype)
    out = ops.vwr_swiglu(x, wg, wi, bm=bm, bk=bk, bn=bn)
    g = ref.matmul_ref(x, wg).astype(jnp.float32)
    h = ref.matmul_ref(x, wi).astype(jnp.float32)
    want = (jax.nn.silu(g) * h).astype(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d,bq,bkv,causal", [
    (2, 64, 4, 4, 16, 32, 32, True),
    (2, 100, 8, 2, 16, 32, 64, True),    # GQA + ragged seq
    (1, 128, 4, 4, 32, 64, 64, False),
    (1, 96, 4, 1, 32, 32, 32, True),     # MQA
    (2, 64, 12, 4, 16, 32, 32, True),    # GQA with non-pow2 group G=3
])
def test_vwr_attention(dtype, b, s, h, kv, d, bq, bkv, causal):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, s, h, d), dtype)
    k = _rand(k2, (b, s, kv, d), dtype)
    v = _rand(k3, (b, s, kv, d), dtype)
    out = ops.vwr_attention(q, k, v, causal=causal, bq=bq, bkv=bkv)
    g = h // kv
    kr = jnp.repeat(k, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vr = jnp.repeat(v, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    want = ref.attention_ref(qf, kr, vr, causal=causal)
    want = want.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **_tol(dtype))


def test_vwr_attention_gqa_zero_copy_vs_oracle():
    """H=8 query heads over KV=2 heads: the zero-copy BlockSpec
    routing (kv block = b // G) must match the dense GQA oracle that
    logically broadcasts each KV head over its group."""
    from repro.models.attention import full_attn_ref
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (2, 96, 8, 32), jnp.float32)
    k = _rand(k2, (2, 96, 2, 32), jnp.float32)
    v = _rand(k3, (2, 96, 2, 32), jnp.float32)
    out = ops.vwr_attention(q, k, v, causal=True, bq=32, bkv=32)
    want = full_attn_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # and it must equal the old head-expanded (materialized) layout
    g = 4
    expanded = ops.vwr_attention(q, jnp.repeat(k, g, 2),
                                 jnp.repeat(v, g, 2),
                                 causal=True, bq=32, bkv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expanded),
                               rtol=1e-6, atol=1e-6)


def test_attention_matches_model_blockwise():
    """Pallas kernel == the model's pure-JAX blockwise path (the one
    the dry-run lowers) — kernel_impl swap is semantics-preserving."""
    from repro.models.attention import blockwise_attn
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (2, 64, 4, 16), jnp.float32)
    k = _rand(k2, (2, 64, 2, 16), jnp.float32)
    v = _rand(k3, (2, 64, 2, 16), jnp.float32)
    a = ops.vwr_attention(q, k, v, causal=True, bq=32, bkv=32)
    b = blockwise_attn(q, k, v, causal=True, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,c,f,kh,act,bias", [
    (1, 9, 9, 8, 8, 3, "relu", True),
    (2, 13, 11, 7, 5, 3, "gelu", True),     # ragged + padding path
    (1, 8, 8, 4, 16, 1, None, True),        # bias only
    (2, 12, 10, 3, 9, 5, "relu", False),    # activation only
])
def test_vwr_conv2d_fused_epilogue(dtype, n, h, w, c, f, kh, act, bias):
    """Fused bias+activation == the unfused two-pass composition (the
    single store applies the epilogue on the fp32 accumulator)."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = _rand(k1, (n, h, w, c), dtype)
    wts = _rand(k2, (kh, kh, c, f), dtype)
    b = _rand(k3, (f,), dtype) if bias else None
    out = ops.vwr_conv2d(x, wts, b, activation=act, bh=4, bf=4)
    want = ref.conv2d_ref(x, wts).astype(jnp.float32)
    if b is not None:
        want = want + b.astype(jnp.float32)
    if act is not None:
        want = {"relu": jax.nn.relu, "gelu": jax.nn.gelu}[act](want)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want.astype(dtype), np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,kv,d,bkv,cur", [
    (2, 64, 4, 4, 16, 32, 50),
    (2, 100, 8, 2, 16, 32, 100),     # GQA + ragged cache -> padding
    (1, 96, 4, 1, 32, 64, 1),        # MQA, single valid position
])
def test_vwr_flash_decode_partials(dtype, b, t, h, kv, d, bkv, cur):
    """Normalized kernel partials == decode_attend_local; the (m, l)
    stats obey the distributed-FlashDecoding combine contract."""
    from repro.models.attention import decode_attend_local, \
        flash_decode_partial
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, h, d), dtype)
    ck = _rand(k2, (b, t, kv, d), dtype)
    cv = _rand(k3, (b, t, kv, d), dtype)
    o_t, m, l = ops.vwr_flash_decode(q, ck, cv, jnp.int32(cur), bkv=bkv)
    got = (o_t / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)
    want = decode_attend_local(q, ck, cv, jnp.arange(t), jnp.int32(cur))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # stats match the XLA partial formulation (same combine contract)
    o_ref, m_ref, l_ref = flash_decode_partial(q, ck, cv, jnp.arange(t),
                                               jnp.int32(cur))
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), **tol)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_ref),
                               rtol=5 * tol["rtol"], atol=5 * tol["atol"])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,d,ps,j", [
    (2, 4, 2, 16, 8, 4),             # GQA
    (3, 4, 1, 32, 4, 6),             # MQA (the absorbed-MLA view)
])
def test_vwr_paged_flash_decode_matches_gather_ref(dtype, b, h, kv, d,
                                                   ps, j):
    """The block-table-indexed paged kernel == the XLA gather reference
    == the dense kernel on the gathered cache, including zero-count
    (masked) pages and per-slot ragged lengths."""
    from repro.models.attention import paged_flash_decode_partial
    n_pages = b * j + 3
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = _rand(k1, (b, h, d), dtype)
    kp = _rand(k2, (n_pages, ps, kv, d), dtype)
    vp = _rand(k3, (n_pages, ps, kv, d), dtype)
    # shuffled disjoint page assignment + ragged per-slot lengths
    perm = jax.random.permutation(k4, n_pages)[:b * j]
    table = perm.reshape(b, j).astype(jnp.int32)
    lens = (jnp.arange(b, dtype=jnp.int32) * (ps + 1) + 3) % (j * ps)
    counts = jnp.clip(lens[:, None] - jnp.arange(j)[None, :] * ps,
                      0, ps).astype(jnp.int32)
    got = ops.vwr_paged_flash_decode(q, kp, vp, table, counts)
    want = paged_flash_decode_partial(q, kp, vp, table, counts)
    tol = _tol(dtype)
    for g, w, name in zip(got, want, ("o_tilde", "m", "l")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=5 * tol["rtol"],
                                   atol=5 * tol["atol"], err_msg=name)
    # and the gathered-dense oracle agrees slot by slot
    from repro.models.attention import decode_attend_local
    dense_k = kp[table].reshape(b, j * ps, kv, d)
    dense_v = vp[table].reshape(b, j * ps, kv, d)
    norm = (got[0] / jnp.maximum(got[2], 1e-30)[..., None])
    for slot in range(b):
        if int(lens[slot]) == 0:
            assert float(jnp.abs(norm[slot]).max()) == 0.0
            continue
        want_o = decode_attend_local(
            q[slot:slot + 1], dense_k[slot:slot + 1],
            dense_v[slot:slot + 1], jnp.arange(j * ps), lens[slot])
        np.testing.assert_allclose(
            np.asarray(norm[slot], np.float32),
            np.asarray(want_o[0], np.float32),
            rtol=5 * tol["rtol"], atol=5 * tol["atol"])


def test_vwr_flash_decode_sharded_offset():
    """pos0 slab offsets partition the softmax: combining two half-
    cache partials reproduces the full-cache result."""
    from repro.models.attention import decode_attend_local
    k1, k2, k3 = jax.random.split(KEY, 3)
    B, T, KV, D, H = 2, 64, 2, 16, 4
    q = _rand(k1, (B, H, D), jnp.float32)
    ck = _rand(k2, (B, T, KV, D), jnp.float32)
    cv = _rand(k3, (B, T, KV, D), jnp.float32)
    cur = jnp.int32(50)
    halves = [ops.vwr_flash_decode(q, ck[:, s], cv[:, s], cur,
                                   pos0=s.start)
              for s in (slice(0, 32), slice(32, 64))]
    m_star = jnp.maximum(halves[0][1], halves[1][1])
    o = sum(o_t * jnp.exp(m - m_star)[..., None] for o_t, m, _ in halves)
    l = sum(l * jnp.exp(m - m_star) for _, m, l in halves)
    got = o / jnp.maximum(l, 1e-30)[..., None]
    want = decode_attend_local(q, ck, cv, jnp.arange(T), cur)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_mla_absorbed_mqa_view_matches_partial_oracle():
    """MLA decode recast as MQA flash-decode (concat latent+rope cache,
    KV=1) must reproduce the absorbed-form einsum partial's normalized
    output — the contract that lets MLA ride the GQA decode path."""
    from repro.common.config import MLAConfig, ModelConfig
    from repro.models import mla
    from repro.models.attention import flash_decode_partial

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
                      vocab=64, dtype="float32", remat="none",
                      mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                    rope_head_dim=8, nope_head_dim=16,
                                    v_head_dim=16))
    p = jax.tree.map(lambda d: d.init(KEY, d.shape, d.dtype),
                     mla.mla_spec(cfg),
                     is_leaf=lambda x: hasattr(x, "init"))
    B, T = 2, 12
    x = _rand(KEY, (B, T, 64), jnp.float32)
    _, (ckv, krope) = mla.mla_attention(p, x, jnp.arange(T), cfg,
                                        causal=True, dense=True)
    q_nope, q_rope = mla.mla_queries(p, x[:, -1:], jnp.arange(T)[-1:],
                                     cfg)
    o_ref, m_ref, l_ref = mla.mla_decode_partial(
        p, q_nope[:, 0], q_rope[:, 0], ckv, krope, jnp.arange(T),
        jnp.int32(T), cfg)
    want = o_ref / np.maximum(np.asarray(l_ref), 1e-30)[..., None]

    q_cat, k_cat, v_cat, r = mla.mla_absorbed_mqa(
        p, q_nope[:, 0], q_rope[:, 0], ckv, krope, cfg)
    # xla registry impl
    o_t, m, l = flash_decode_partial(q_cat, k_cat, v_cat, jnp.arange(T),
                                     jnp.int32(T))
    got = (o_t / np.maximum(np.asarray(l), 1e-30)[..., None])[..., :r]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # pallas registry impl (the VWR flash-decode kernel)
    o_t2, m2, l2 = ops.vwr_flash_decode(q_cat, k_cat, v_cat,
                                        jnp.int32(T), bkv=32)
    got2 = (o_t2 / np.maximum(np.asarray(l2), 1e-30)[..., None])[..., :r]
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_step_pallas_matches_xla():
    """cfg.kernel_impl='pallas' decode (the VWR flash-decode kernel
    inside lm._decode_gqa) is semantics-preserving vs the einsum/XLA
    decode path, across several steps of cache growth."""
    from repro.common.config import ModelConfig
    from repro.models import lm

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                      vocab=256, dtype="float32", remat="none",
                      qkv_bias=True)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    B, T = 2, 16
    cache_x = lm.init_cache(cfg, B, T)
    cache_p = lm.init_cache(cfg, B, T)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B,), 0, 256)
    pcfg = cfg.replace(kernel_impl="pallas")
    for step in range(3):
        bx = {"token": tok, "cur_len": jnp.int32(step), "cache": cache_x}
        bp = {"token": tok, "cur_len": jnp.int32(step), "cache": cache_p}
        want, cache_x = lm.decode_step(params, bx, cfg)
        got, cache_p = lm.decode_step(params, bp, pcfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        tok = jnp.argmax(want, -1).astype(jnp.int32)
