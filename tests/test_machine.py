"""Machine/energy model tests (eq. 1-2, Fig. 2b, Table 1) + ISA
invariants (hypothesis)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import isa, machine
from repro.core.machine import (PAPER_EXAMPLE, ProvetConfig,
                                aspect_ratio_sweep, crossbar_cost,
                                shuffler_cost, sram_bit_energy_fj,
                                sram_word_energy_fj)


def test_eq2_per_bit_energy_drops_with_width():
    """Fig. 2b: at fixed capacity, wider+shallower => cheaper per bit."""
    cap = 64 * 1024 * 8
    sweep = aspect_ratio_sweep(cap)
    widths = sorted(sweep)
    es = [sweep[w]["e_per_bit_fj"] for w in widths]
    assert all(a > b for a, b in zip(es, es[1:]))
    bws = [sweep[w]["bw_bits_per_cycle"] for w in widths]
    assert all(a < b for a, b in zip(bws, bws[1:]))


def test_eq1_eq2_consistency():
    for w in (128, 1024, 4096):
        for d in (1, 8, 32):
            assert abs(sram_word_energy_fj(w, d) / w
                       - sram_bit_energy_fj(w, d)) < 1e-9


def test_table1_shuffler_vs_crossbar():
    """Table 1: gates 16k vs 86k (x5.38), area 0.13 vs 0.88 mm^2
    (x6.82), wire 4.3 vs 33.1 mm (x7.67) at the inferred config."""
    n = machine.PAPER_TABLE1_ENDPOINTS
    r = machine.PAPER_TABLE1_REACH
    sh = shuffler_cost(n, r)
    xb = crossbar_cost(n)
    assert abs(sh["gates"] - 16e3) / 16e3 < 0.1
    assert abs(xb["gates"] - 86e3) / 86e3 < 0.1
    assert abs(sh["wire_mm"] - 4.3) / 4.3 < 0.15
    assert abs(xb["wire_mm"] - 33.1) / 33.1 < 0.15
    assert 4.5 < xb["gates"] / sh["gates"] < 7.0
    assert 5.0 < xb["area_mm2"] / sh["area_mm2"] < 8.0
    assert 6.0 < xb["wire_mm"] / sh["wire_mm"] < 9.0


def test_width_ratio_semantics():
    cfg = ProvetConfig(sram_width=512, vfu_width=64, n_vfus=1)
    assert cfg.width_ratio == 8
    cfg = ProvetConfig(sram_width=512, vfu_width=64, n_vfus=4)
    assert cfg.width_ratio == 2


@settings(max_examples=20, deadline=None)
@given(shift=st.integers(-8, 8), seed=st.integers(0, 100))
def test_perm_shift_invertible(shift, seed):
    cfg = ProvetConfig(vfu_shuffle_range=8)
    m = isa.ProvetMachine(cfg)
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal((1, cfg.vfu_width)).astype(np.float32)
    m.regs["R1"] = vals.copy()
    m.step(isa.PERM(src="R1", dst="R2", shift=shift))
    m.step(isa.PERM(src="R2", dst="R3", shift=-shift))
    k = abs(shift)
    if shift >= 0:
        np.testing.assert_array_equal(m.regs["R3"][0, : cfg.vfu_width - k],
                                      vals[0, : cfg.vfu_width - k])
    else:
        np.testing.assert_array_equal(m.regs["R3"][0, k:], vals[0, k:])


@settings(max_examples=20, deadline=None)
@given(row=st.integers(0, 31), seed=st.integers(0, 100))
def test_rlb_wlb_roundtrip(row, seed):
    cfg = ProvetConfig()
    m = isa.ProvetMachine(cfg)
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(cfg.sram_width).astype(np.float32)
    m.sram[row] = data
    m.step(isa.RLB(vwr=0, row=row))
    m.step(isa.WLB(vwr=0, row=(row + 1) % cfg.sram_depth))
    np.testing.assert_array_equal(m.sram[(row + 1) % cfg.sram_depth],
                                  data)
    assert m.c.sram_reads == 1 and m.c.sram_writes == 1
    assert m.c.cycles == 2
    assert m.c.energy_fj > 0


@settings(max_examples=15, deadline=None)
@given(shift=st.integers(-8, 8), seed=st.integers(0, 50))
def test_glmv_roll(shift, seed):
    cfg = ProvetConfig(tile_shuffle_range=8)
    m = isa.ProvetMachine(cfg)
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(cfg.sram_width).astype(np.float32)
    m.vwr[0] = data
    m.step(isa.GLMV(vwr=0, block_shift=shift))
    np.testing.assert_array_equal(
        m.vwr[0], np.roll(data, shift * cfg.vfu_width))


def test_vfux_modes():
    cfg = ProvetConfig()
    m = isa.ProvetMachine(cfg)
    a = np.linspace(-2, 2, cfg.vfu_width, dtype=np.float32)[None]
    b = np.full((1, cfg.vfu_width), 0.5, np.float32)
    m.regs["R1"], m.regs["R4"] = a.copy(), b.copy()
    m.step(isa.VFUX(mode="mult", in1="R1", in2="R4", out="R2"))
    np.testing.assert_allclose(m.regs["R2"], a * b)
    m.step(isa.VFUX(mode="relu", in1="R1", out="R2"))
    np.testing.assert_allclose(m.regs["R2"], np.maximum(a, 0))
    m.step(isa.VFUX(mode="mac", in1="R1", in2="R4", out="R3", acc="R3"))
    np.testing.assert_allclose(m.regs["R3"], a * b, rtol=1e-6)
    m.step(isa.VFUX(mode="sigmoid", in1="R1", out="R2"))
    np.testing.assert_allclose(m.regs["R2"], 1 / (1 + np.exp(-a)),
                               rtol=1e-5)
    assert m.c.compute_instrs == 4


def test_energy_accounting_monotone():
    """Wide SRAM accesses dominate VWR accesses in the energy ledger —
    the hierarchy-cost ordering the paper's design relies on."""
    cfg = PAPER_EXAMPLE
    m = isa.ProvetMachine(cfg)
    m.step(isa.RLB(vwr=0, row=0))
    e_sram = m.c.energy_fj
    m2 = isa.ProvetMachine(cfg)
    m2.step(isa.VMV(vwr=0, slice_idx=0, dst="R1"))
    e_vwr = m2.c.energy_fj
    assert e_sram > 5 * e_vwr
