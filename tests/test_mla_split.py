"""Split-operand MLA decode tests: the copy-free
``decode_partial_mla`` / ``decode_partial_mla_paged`` ops must be
equivalent to the concatenated absorbed-MQA view (k_cat/v_cat +
``decode_partial``) — numerically at the op level and token-for-token
through the engine — plus the block-table width bucketing pins
(bucketed streams identical to fixed-width, dispatch cache keyed by
page geometry)."""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MLAConfig, ModelConfig
from repro.engine import DecodeEngine, EngineConfig, Request, Scheduler
from repro.engine.paged_cache import bucket_table_width
from repro.kernels import dispatch as D
from repro.models import mla as MLA

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32,
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              rope_head_dim=8, nope_head_dim=16,
                              v_head_dim=16))
    base.update(kw)
    return ModelConfig(**base)


# ----------------------------------------------------------------------
# concatenated-view reference impls (the pre-split production path):
# ``MLA.mla_concat_view``'s q/k/v concats feeding the plain
# ``decode_partial`` ops, output sliced back to the latent dims.
# Registered over the split ops to drive the whole engine through the
# concat path for the bit-exactness pins.
# ----------------------------------------------------------------------

def _concat_mla_partial(q_abs, q_rope, c_kv, k_rope, cur_len, pos0=0, *,
                        scale, tune=True):
    q_cat, k_cat, v_cat, r = MLA.mla_concat_view(q_abs, q_rope, c_kv,
                                                 k_rope, scale)
    o_t, m, l = D.dispatch("decode_partial", "xla", q_cat, k_cat, v_cat,
                           cur_len, pos0)
    return o_t[..., :r], m, l


def _concat_mla_paged_partial(q_abs, q_rope, ckv_pool, krope_pool,
                              table, counts, *, scale, page_size=None,
                              max_pages=None, tune=True):
    q_cat, k_cat, v_cat, r = MLA.mla_concat_view(q_abs, q_rope,
                                                 ckv_pool, krope_pool,
                                                 scale)
    o_t, m, l = D.dispatch("decode_partial_paged", "xla", q_cat, k_cat,
                           v_cat, table, counts)
    return o_t[..., :r], m, l


@contextlib.contextmanager
def _concat_registered():
    """Temporarily make the concat view the 'xla' backend of the split
    ops (re-registration is the supported test seam in the dispatch
    registry)."""
    saved = {op: dict(D._REGISTRY[op])
             for op in ("decode_partial_mla", "decode_partial_mla_paged")}
    try:
        D.register("decode_partial_mla", "xla")(_concat_mla_partial)
        D.register("decode_partial_mla_paged", "xla")(
            _concat_mla_paged_partial)
        yield
    finally:
        for op, table in saved.items():
            D._REGISTRY[op] = table


def _rand_split_inputs(B=2, H=4, r=16, rope=8, T=20):
    ks = jax.random.split(KEY, 4)
    return (jax.random.normal(ks[0], (B, H, r)),
            jax.random.normal(ks[1], (B, H, rope)),
            jax.random.normal(ks[2], (B, T, r)),
            jax.random.normal(ks[3], (B, T, rope)))


# ------------------------------------------------- op-level equivalence


def test_split_partial_matches_concat_view():
    """Split-operand XLA reference == concatenated k_cat/v_cat view
    (same softmax statistics, latent-sliced output), and the pallas
    split kernel matches its own XLA reference."""
    q_abs, q_rope, ckv, krope = _rand_split_inputs()
    scale = 1.0 / (24 ** 0.5)
    cur = jnp.int32(13)
    o_s, m_s, l_s = D.dispatch("decode_partial_mla", "xla", q_abs,
                               q_rope, ckv, krope, cur, scale=scale)
    o_c, m_c, l_c = _concat_mla_partial(q_abs, q_rope, ckv, krope, cur,
                                        scale=scale)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_c),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m_s), np.asarray(m_c),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_c),
                               rtol=1e-5, atol=1e-5)
    o_p, m_p, l_p = D.dispatch("decode_partial_mla", "pallas", q_abs,
                               q_rope, ckv, krope, cur, scale=scale)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_s),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_s),
                               rtol=1e-5, atol=1e-5)


def test_split_paged_partial_matches_concat_view():
    """Paged split-operand op (xla gather ref AND pallas scalar-
    prefetch kernel) == concatenated pool view, with count-0 pages
    (unallocated / foreign) masked identically."""
    B, H, r, rope, ps, J, n_pages = 2, 4, 16, 8, 4, 5, 12
    ks = jax.random.split(KEY, 4)
    q_abs = jax.random.normal(ks[0], (B, H, r))
    q_rope = jax.random.normal(ks[1], (B, H, rope))
    ckv_pool = jax.random.normal(ks[2], (n_pages, ps, r))
    krope_pool = jax.random.normal(ks[3], (n_pages, ps, rope))
    table = jnp.asarray([[0, 2, 4, 0, 0], [1, 3, 5, 7, 0]], jnp.int32)
    lens = jnp.asarray([9, 18], jnp.int32)
    counts = jnp.clip(lens[:, None] - jnp.arange(J)[None, :] * ps,
                      0, ps).astype(jnp.int32)
    scale = 1.0 / (24 ** 0.5)
    want = _concat_mla_paged_partial(q_abs, q_rope, ckv_pool,
                                     krope_pool, table, counts,
                                     scale=scale)
    for backend in ("xla", "pallas"):
        got = D.dispatch("decode_partial_mla_paged", backend, q_abs,
                         q_rope, ckv_pool, krope_pool, table, counts,
                         scale=scale, page_size=ps, max_pages=J)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=backend)


# ------------------------------------------------- engine token pins


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_engine_split_vs_concat_token_streams(paged, rng):
    """Greedy MLA generation through the split-operand path is token-
    for-token identical to the concatenated k_cat/v_cat path, dense
    cache and paged pools alike."""
    cfg = _cfg()
    B, P, G = 2, 8, 6
    kw = dict(paged=True, page_size=4) if paged else {}
    eng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G, **kw))
    batch = {"tokens": jnp.asarray(rng.integers(2, cfg.vocab, (B, P)),
                                   jnp.int32)}
    got, _ = eng.generate(batch, gen=G)
    with _concat_registered():
        eng_c = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                               **kw), params=eng.params)
        want, _ = eng_c.generate(batch, gen=G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------- table-width buckets


def test_bucket_table_width():
    assert bucket_table_width(0, 8) == 1
    assert bucket_table_width(1, 8) == 1
    assert bucket_table_width(2, 8) == 2
    assert bucket_table_width(3, 8) == 4
    assert bucket_table_width(5, 8) == 8
    assert bucket_table_width(8, 8) == 8
    assert bucket_table_width(9, 8) == 8          # clamped
    assert bucket_table_width(3, 6) == 4          # non-pow2 max_pages
    assert bucket_table_width(5, 6) == 6


@pytest.mark.parametrize("mla", [False, True], ids=["gqa", "mla"])
def test_scheduler_bucketed_tables_match_fixed_width(mla, rng):
    """Bucketed decode steps produce token streams identical to
    fixed-width max_pages runs, including a slot that crosses a bucket
    boundary mid-generation (2 live pages -> 3, bucket 2 -> 4), with
    admission/retire semantics untouched."""
    cfg = _cfg() if mla else _cfg(mla=None)
    P, G = 7, 10                      # 7+1 fills page 2 mid-stream
    ecfg = EngineConfig(batch=2, max_len=32, paged=True, page_size=4)
    eng = DecodeEngine(cfg, ecfg)
    reqs = [Request(rid=i, tokens=rng.integers(
                0, cfg.vocab, (P,)).astype(np.int32), gen=G)
            for i in range(3)]

    def run(bucket):
        sched = Scheduler(eng, bucket_tables=bucket)
        for r in reqs:
            sched.submit(r)
        return sched.run(), sched.stats

    got, stats_b = run(True)
    want, stats_f = run(False)
    assert set(got) == set(want) == {0, 1, 2}
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid],
                                      err_msg=f"request {rid}")
    # fixed-width stages max_pages columns every step...
    assert set(stats_f["table_widths"]) == {eng.max_pages}
    # ...bucketing stages only live pages and crosses 2 -> 4 mid-run
    assert set(stats_b["table_widths"]) == {2, 4}
    assert max(stats_b["table_widths"]) < eng.max_pages
    # same scheduling either way: identical admission/retire counts
    for k in ("prefills", "admitted", "retired", "steps", "preempted"):
        assert stats_b[k] == stats_f[k], k


# ------------------------------------------------- dispatch geometry


def test_paged_dispatch_cache_keyed_by_page_geometry(tmp_path,
                                                     monkeypatch):
    """A measured 'auto' winner for one (page_size, max_pages) must not
    replay for another: the geometry statics are folded into the
    dispatch cache key alongside the operand shapes."""
    from repro.kernels import autotune
    from repro.kernels import ops as kops

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    autotune.reset()
    B, H, KV, Dh, ps, J, n_pages = 2, 4, 2, 16, 4, 6, 12
    q = jnp.zeros((B, H, Dh))
    kp = jnp.zeros((n_pages, ps, KV, Dh))
    tbl = jnp.zeros((B, J), jnp.int32)
    cnt = jnp.zeros((B, J), jnp.int32)
    args = (q, kp, kp, tbl, cnt)
    geom = {"page_size": ps, "max_pages": J}
    other = {"page_size": 2 * ps, "max_pages": J}

    # distinct static kwargs -> distinct signatures on the same arrays
    assert (D._arg_signature(args, geom)
            != D._arg_signature(args, other))

    # persist an 'xla' winner under geometry A; replay honors it for A
    # and falls back to the prior (pallas-first) for geometry B
    shape, dtype = D._arg_signature(args, geom)
    tag = kops._backend_tag(kops._auto_interpret(None))
    key = autotune.cache_key("dispatch:decode_partial_paged", shape,
                             dtype, tag)
    autotune._persist(autotune.cache_path(), {key: {"blocks": ["xla"]}})
    assert D.cached_backend("decode_partial_paged", "auto", args,
                            geom) == "xla"
    assert D.cached_backend("decode_partial_paged", "auto", args,
                            other) == "pallas"


def test_paged_dispatch_cache_keyed_by_pool_dtype(tmp_path,
                                                  monkeypatch):
    """A measured 'auto' winner for bf16 pools must not replay for the
    int8+scales call at the same shapes: the query leads both operand
    lists in fp32, so keying only the FIRST array dtype collided them.
    Every distinct operand dtype joins the signature."""
    from repro.kernels import autotune
    from repro.kernels import ops as kops

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    autotune.reset()
    B, H, KV, Dh, ps, J, n_pages = 2, 4, 2, 16, 4, 6, 12
    q = jnp.zeros((B, H, Dh))                       # fp32 leads both
    tbl = jnp.zeros((B, J), jnp.int32)
    cnt = jnp.zeros((B, J), jnp.int32)
    geom = {"page_size": ps, "max_pages": J}
    kp16 = jnp.zeros((n_pages, ps, KV, Dh), jnp.bfloat16)
    bf16_args = (q, kp16, kp16, tbl, cnt)
    kp8 = jnp.zeros((n_pages, ps, KV, Dh), jnp.int8)
    sc = jnp.zeros((n_pages, KV), jnp.float32)
    q8_args = (q, kp8, kp8, sc, sc, tbl, cnt)

    sig16 = D._arg_signature(bf16_args, geom)
    sig8 = D._arg_signature(q8_args, geom)
    assert sig16 != sig8
    assert "int8" in sig8[1] and "int8" not in sig16[1]

    # persist an 'xla' winner for the bf16 pools; the q8 twin still
    # resolves through the prior (pallas-first), not the bf16 entry
    tag = kops._backend_tag(kops._auto_interpret(None))
    key = autotune.cache_key("dispatch:decode_partial_paged", sig16[0],
                             sig16[1], tag)
    autotune._persist(autotune.cache_path(), {key: {"blocks": ["xla"]}})
    assert D.cached_backend("decode_partial_paged", "auto", bf16_args,
                            geom) == "xla"
    assert D.cached_backend("decode_partial_paged_q8", "auto", q8_args,
                            geom) == "pallas"
    assert D.cached_backend("decode_partial_paged", "auto", q8_args,
                            geom) == "pallas"
