"""Model-numerics tests: each fused/chunked formulation against its
naive oracle, plus MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import (MLAConfig, Mamba2Config, ModelConfig,
                                 MoEConfig, XLSTMConfig)
from repro.models import attention as A
from repro.models import lm, mla, moe, ssm, xlstm

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------- attn

def test_blockwise_attention_matches_dense():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 100, 8, 16))
    k = jax.random.normal(k2, (2, 100, 2, 16))
    v = jax.random.normal(k3, (2, 100, 2, 16))
    out = A.blockwise_attn(q, k, v, causal=True, block_q=32, block_kv=48)
    want = A.full_attn_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_partial_combine():
    """Sharded partial softmax combined == monolithic decode."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    B, T, KV, Dh, H = 2, 64, 2, 16, 4
    q = jax.random.normal(k1, (B, H, Dh))
    ck = jax.random.normal(k2, (B, T, KV, Dh))
    cv = jax.random.normal(k3, (B, T, KV, Dh))
    cur = jnp.int32(50)
    want = A.decode_attend_local(q, ck, cv, jnp.arange(T), cur)

    # two shards, manual combine
    o1, m1, l1 = A.flash_decode_partial(q, ck[:, :32], cv[:, :32],
                                        jnp.arange(0, 32), cur)
    o2, m2, l2 = A.flash_decode_partial(q, ck[:, 32:], cv[:, 32:],
                                        jnp.arange(32, 64), cur)
    m = jnp.maximum(m1, m2)
    num = o1 * jnp.exp(m1 - m)[..., None] + o2 * jnp.exp(m2 - m)[..., None]
    den = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
    got = (num / den[..., None]).astype(want.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- mamba2

def test_mamba2_closed_form_matches_scan():
    cfg = _cfg(family="hybrid",
               mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8,
                                   attn_every=2))
    p = jax.tree.map(
        lambda d: d.init(KEY, d.shape, d.dtype),
        ssm.mamba2_spec(cfg),
        is_leaf=lambda x: hasattr(x, "init"))
    x = jax.random.normal(KEY, (2, 32, 64))
    y1, s1 = ssm.mamba2_forward(p, x, cfg)
    y2, s2 = ssm.mamba2_forward(p, x, cfg.replace(accounting=True))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1.ssm), np.asarray(s2.ssm),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_chunk_invariance():
    cfg8 = _cfg(family="hybrid",
                mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8,
                                    attn_every=2))
    cfg16 = cfg8.replace(mamba2=Mamba2Config(d_state=8, head_dim=16,
                                             chunk=16, attn_every=2))
    p = jax.tree.map(lambda d: d.init(KEY, d.shape, d.dtype),
                     ssm.mamba2_spec(cfg8),
                     is_leaf=lambda x: hasattr(x, "init"))
    x = jax.random.normal(KEY, (2, 32, 64))
    y1, _ = ssm.mamba2_forward(p, x, cfg8)
    y2, _ = ssm.mamba2_forward(p, x, cfg16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_mamba2_decode_matches_forward():
    """Prefill then stepwise decode == one long forward."""
    cfg = _cfg(family="hybrid",
               mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8,
                                   attn_every=2))
    p = jax.tree.map(lambda d: d.init(KEY, d.shape, d.dtype),
                     ssm.mamba2_spec(cfg),
                     is_leaf=lambda x: hasattr(x, "init"))
    x = jax.random.normal(KEY, (1, 24, 64))
    y_full, _ = ssm.mamba2_forward(p, x, cfg)
    y_pre, st = ssm.mamba2_forward(p, x[:, :16], cfg)
    ys = [y_pre]
    for t in range(16, 24):
        y_t, st = ssm.mamba2_step(p, x[:, t], st, cfg)
        ys.append(y_t[:, None])
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- xlstm

def test_mlstm_chunkwise_matches_naive():
    B, S, H, P = 2, 32, 2, 8
    ks = jax.random.split(KEY, 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, P)) for i in range(3))
    li = jax.random.normal(ks[3], (B, S, H))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    st0 = (jnp.zeros((B, H, P, P)), jnp.zeros((B, H, P)),
           jnp.full((B, H), -1e30))
    h1, s1 = xlstm.mlstm_chunkwise(q, k, v, li, lf, st0, chunk=8)
    h2, s2 = xlstm.mlstm_ref(q, k, v, li, lf, st0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)
    # states agree up to the shared stabilizer convention
    c1 = s1[0] * jnp.exp(s1[2])[..., None, None]
    c2 = s2[0] * jnp.exp(s2[2])[..., None, None]
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=2e-3,
                               atol=2e-3)


def test_mlstm_chunkwise_unroll_equal():
    B, S, H, P = 1, 32, 2, 8
    ks = jax.random.split(KEY, 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, P)) for i in range(3))
    li = jax.random.normal(ks[3], (B, S, H))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)))
    st0 = (jnp.zeros((B, H, P, P)), jnp.zeros((B, H, P)),
           jnp.full((B, H), -1e30))
    h1, _ = xlstm.mlstm_chunkwise(q, k, v, li, lf, st0, 8, unroll=False)
    h2, _ = xlstm.mlstm_chunkwise(q, k, v, li, lf, st0, 8, unroll=True)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-6,
                               atol=1e-6)


def test_slstm_step_matches_forward():
    cfg = _cfg(family="ssm", n_kv_heads=4,
               xlstm=XLSTMConfig(slstm_every=2, chunk=8))
    p = jax.tree.map(lambda d: d.init(KEY, d.shape, d.dtype),
                     xlstm.slstm_spec(cfg),
                     is_leaf=lambda x: hasattr(x, "init"))
    x = jax.random.normal(KEY, (2, 12, 64))
    y_full, st_full = xlstm.slstm_forward(p, x, cfg)
    st = xlstm.slstm_init_state(cfg, 2)
    ys = []
    for t in range(12):
        y_t, st = xlstm.slstm_step(p, x[:, t], st, cfg)
        ys.append(y_t[:, None])
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- moe

def test_moe_positions_sort_equals_cumsum():
    idx = jax.random.randint(KEY, (3, 64), 0, 8)
    p1 = moe._positions_cumsum(idx, 8)
    p2 = moe._positions_sort(idx, 8)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_moe_matches_dense_reference():
    """With capacity large enough to never drop, capacity dispatch ==
    dense per-expert evaluation."""
    cfg = _cfg(family="moe",
               moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                             capacity_factor=4.0, norm_topk=True))
    p = jax.tree.map(lambda d: d.init(KEY, d.shape, d.dtype),
                     moe.moe_spec(cfg),
                     is_leaf=lambda x: hasattr(x, "init"))
    x = jax.random.normal(KEY, (2, 16, 64))
    y, aux = moe.moe_ffn(p, x, cfg)
    assert float(aux["drop_frac"]) == 0.0

    probs, sel, _ = moe.router_scores(p, x, cfg)
    gates, idx = moe.top_k_gates(probs, sel, cfg)

    def expert(e, xx):
        h = xx @ p["wi"][e]
        g = xx @ p["wg"][e]
        return (jax.nn.silu(g) * h) @ p["wo"][e]

    want = jnp.zeros_like(x)
    for e in range(4):
        ye = expert(e, x)
        w_e = jnp.where(idx == e, gates, 0.0).sum(-1)
        want = want + ye * w_e[..., None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_counted():
    cfg = _cfg(family="moe",
               moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                             capacity_factor=0.25))
    p = jax.tree.map(lambda d: d.init(KEY, d.shape, d.dtype),
                     moe.moe_spec(cfg),
                     is_leaf=lambda x: hasattr(x, "init"))
    x = jax.random.normal(KEY, (1, 32, 64))
    _, aux = moe.moe_ffn(p, x, cfg)
    assert 0.0 < float(aux["drop_frac"]) < 1.0


# ---------------------------------------------------------------- mla

def test_mla_decode_absorbed_matches_expanded():
    """Absorbed decode scores/values == expanded-form attention on the
    same (prefix + new token) sequence."""
    cfg = _cfg(mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                             rope_head_dim=8, nope_head_dim=16,
                             v_head_dim=16))
    p = jax.tree.map(lambda d: d.init(KEY, d.shape, d.dtype),
                     mla.mla_spec(cfg),
                     is_leaf=lambda x: hasattr(x, "init"))
    B, T = 2, 12
    x = jax.random.normal(KEY, (B, T, 64))
    positions = jnp.arange(T)

    # expanded full-sequence attention, last token's output
    out_full, (ckv, krope) = mla.mla_attention(p, x, positions, cfg,
                                               causal=True, dense=True)
    want = out_full[:, -1]

    # absorbed decode of the last token against the cached latents
    q_nope, q_rope = mla.mla_queries(p, x[:, -1:], positions[-1:], cfg)
    o_t, m, l = mla.mla_decode_partial(
        p, q_nope[:, 0], q_rope[:, 0], ckv, krope, jnp.arange(T),
        jnp.int32(T), cfg)
    o = o_t / jnp.maximum(l, 1e-30)[..., None]
    got = mla.mla_decode_finish(p, o.astype(jnp.float32), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------- lm e2e

def test_dense_decode_matches_prefill_logits():
    """Teacher-forced decode reproduces full-forward logits."""
    cfg = _cfg(n_layers=2)
    params = lm.init(cfg, KEY)
    B, S = 2, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)

    out = lm.backbone(params, tokens, cfg)
    logits_all = lm._logits(params, out.h, cfg)

    cache = lm.init_cache(cfg, B, S)
    logits_inc = []
    for t in range(S):
        lg, cache = lm.decode_step(
            params, {"token": tokens[:, t], "cur_len": jnp.int32(t),
                     "cache": cache}, cfg)
        logits_inc.append(lg[:, None])
    got = jnp.concatenate(logits_inc, 1)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(logits_all, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_ce_loss_chunked_equals_whole():
    cfg = _cfg()
    params = lm.init(cfg, KEY)
    h = jax.random.normal(KEY, (2, 16, 64))
    labels = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    mask = jnp.ones((2, 16))
    l1, _ = lm.ce_loss(params, h, labels, mask, cfg)
    l2, _ = lm.ce_loss(params, h, labels, mask,
                       cfg.replace(logits_chunk=5))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
