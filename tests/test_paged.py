"""Paged KV cache + continuous batching tests: paged-vs-dense engine
equivalence (GQA / absorbed-MLA / cross-attention), scheduler slot
reuse and per-request rejection of never-admittable requests, the
page allocator, and the sampled-decode RNG fold_in regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MLAConfig, ModelConfig, MoEConfig
from repro.engine import (DecodeEngine, EngineConfig, PageAllocator,
                          PagePoolExhausted, Request, RequestStatus,
                          Scheduler)
from repro.engine import paged_cache as PC

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


def _mla_cfg():
    return _cfg(mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              rope_head_dim=8, nope_head_dim=16,
                              v_head_dim=16))


def _audio_cfg():
    return _cfg(family="audio", enc_layers=2, frontend="audio",
                frontend_dim=24)


def _engines(cfg, B=2, P=8, G=6, page_size=4, **paged_kw):
    """(dense engine, paged engine) sharing one parameter tree."""
    dense = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G))
    paged = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                           paged=True,
                                           page_size=page_size,
                                           **paged_kw),
                         params=dense.params)
    return dense, paged


def _batch(cfg, B, P, rng):
    batch = {"tokens": jnp.asarray(rng.integers(2, cfg.vocab, (B, P)),
                                   jnp.int32)}
    if cfg.family == "audio":
        batch["frontend_emb"] = jnp.asarray(
            rng.standard_normal((B, P, cfg.frontend_dim)), jnp.float32)
    return batch


# ------------------------------------------------- paged == dense


@pytest.mark.parametrize("make_cfg", [_cfg, _mla_cfg, _audio_cfg],
                         ids=["gqa", "mla", "cross"])
def test_paged_engine_matches_dense(make_cfg, rng):
    """Greedy decode through the paged engine is token-for-token
    identical to the dense-cache engine (GQA, absorbed-MLA and
    encoder-decoder cross-attention families)."""
    cfg = make_cfg()
    B, P, G = 2, 8, 6
    dense, paged = _engines(cfg, B=B, P=P, G=G)
    batch = _batch(cfg, B, P, rng)
    want, _ = dense.generate(batch, gen=G)
    got, _ = paged.generate(batch, gen=G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_engine_matches_dense_moe_mla(rng):
    """The moe family splits the pool per layer group (dense-prefix +
    moe stacks): paged decode still matches, with MLA latent pools."""
    cfg = _cfg(family="moe",
               moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                             first_k_dense=1, d_ff_dense=128,
                             capacity_factor=4.0),
               mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                             rope_head_dim=8, nope_head_dim=16,
                             v_head_dim=16))
    dense, paged = _engines(cfg, B=2, P=8, G=5)
    batch = _batch(cfg, 2, 8, rng)
    want, _ = dense.generate(batch, gen=5)
    got, _ = paged.generate(batch, gen=5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_rejects_recurrent_families():
    cfg = _cfg()
    with pytest.raises(ValueError, match="recurrent state"):
        PC.check_family(cfg.replace(family="hybrid"))
    with pytest.raises(ValueError, match="recurrent state"):
        DecodeEngine(cfg.replace(family="ssm"),
                     EngineConfig(batch=1, max_len=8, paged=True))


def test_paged_decode_step_requires_block_table():
    cfg = _cfg()
    eng = DecodeEngine(cfg, EngineConfig(batch=1, max_len=8, paged=True,
                                         page_size=4))
    logits, cache = eng.prefill({"tokens": jnp.zeros((1, 4), jnp.int32)})
    with pytest.raises(ValueError, match="block_table"):
        eng.decode_step(jnp.zeros((1,), jnp.int32), 4, cache)


# ------------------------------------------------- scheduler


def test_scheduler_slot_reuse_and_no_reprefill(rng):
    """3 requests over 2 slots: the shortest retires, frees its slot +
    pages, the third admits into the reused slot, and every stream
    matches a solo engine run — with exactly one prefill per request
    (survivors are never re-prefilled when slots turn over)."""
    cfg = _cfg()
    P = 8
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=P + 8,
                                         paged=True, page_size=4,
                                         n_pages=10))
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab, (P,)).astype(
                        np.int32),
                    gen=g)
            for i, g in enumerate((3, 7, 5))]
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    # only 2 slots: request 2 must wait for a retirement
    sched.admit()
    assert sched.n_active == 2 and len(sched.pending) == 1
    out = sched.run()
    assert set(out) == {0, 1, 2}
    assert sched.stats["prefills"] == 3
    assert sched.stats["retired"] == 3
    # pool fully drained after the stream
    assert sched.allocator.free_pages == eng.n_pages

    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=P + 8),
                        params=eng.params)
    for r in reqs:
        want, _ = solo.generate(
            {"tokens": jnp.asarray(r.tokens)[None]}, gen=r.gen)
        np.testing.assert_array_equal(out[r.rid], np.asarray(want[0]),
                                      err_msg=f"request {r.rid}")


def test_scheduler_rejects_unadmittable_without_losing_results(rng):
    """Regression: a request larger than the whole pool used to raise
    ``PagePoolExhausted`` out of ``run()``, LOSING every already-
    finished result.  It is now REJECTED individually (with a reason)
    and the stream keeps serving: the good request's tokens survive."""
    cfg = _cfg()
    eng = DecodeEngine(cfg, EngineConfig(batch=1, max_len=16,
                                         paged=True, page_size=4,
                                         n_pages=2))
    sched = Scheduler(eng)
    good = Request(rid="good", tokens=rng.integers(
        0, cfg.vocab, (4,)).astype(np.int32), gen=3)
    sched.submit(good)
    # pool smaller than this prompt's page need: admit can never succeed
    sched.submit(Request(rid="huge", tokens=np.zeros(12, np.int32),
                         gen=2))
    out = sched.run()                   # does NOT raise
    assert set(out) == {"good", "huge"}
    assert out["good"].status is RequestStatus.FINISHED
    assert len(out["good"]) == 3
    assert out["huge"].status is RequestStatus.REJECTED
    assert "pool" in out["huge"].error
    assert len(out["huge"]) == 0
    assert sched.stats["rejected"] == 1
    assert sched.allocator.free_pages == eng.n_pages
    sched.allocator.check()
    # the solo stream still matches
    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=16),
                        params=eng.params)
    want, _ = solo.generate({"tokens": jnp.asarray(good.tokens)[None]},
                            gen=3)
    np.testing.assert_array_equal(out["good"], np.asarray(want[0]))


def test_scheduler_waits_for_pages_then_admits(rng):
    """A pool too small for two concurrent requests serializes them
    instead of failing: the second admits after the first retires."""
    cfg = _cfg()
    P = 8
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=P + 4,
                                         paged=True, page_size=4,
                                         n_pages=3))
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab, (P,)).astype(
                        np.int32), gen=2)
            for i in range(2)]
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    sched.admit()
    assert sched.n_active == 1          # second waits on pages
    out = sched.run()
    assert set(out) == {0, 1}
    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=P + 4),
                        params=eng.params)
    for r in reqs:
        want, _ = solo.generate(
            {"tokens": jnp.asarray(r.tokens)[None]}, gen=r.gen)
        np.testing.assert_array_equal(out[r.rid], np.asarray(want[0]))


def test_scheduler_full_budget_prompt_fits_table(rng):
    """Regression: a prompt that exactly fills the max_len page budget
    (P == max_len, P % page_size == 0, gen == 1) used to request one
    page more than the block table has columns and crashed on the row
    write.  The decode-write page is only reserved when a decode write
    is coming."""
    cfg = _cfg()
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=16,
                                         paged=True, page_size=8))
    sched = Scheduler(eng)
    toks = rng.integers(0, cfg.vocab, (16,)).astype(np.int32)
    sched.submit(Request(rid=0, tokens=toks, gen=1))
    out = sched.run()
    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=16),
                        params=eng.params)
    want, _ = solo.generate({"tokens": jnp.asarray(toks)[None]}, gen=1)
    np.testing.assert_array_equal(out[0], np.asarray(want[0]))
    assert sched.allocator.free_pages == eng.n_pages


def test_scheduler_preempts_instead_of_dying(rng):
    """Regression: mid-stream page growth on a dry pool used to raise
    out of step(), losing every in-flight request.  The oversubscribed
    pool now preempts the latest-admitted slot (recompute preemption)
    and every request still completes with its full token budget."""
    cfg = _cfg()
    P, G = 8, 16
    # 4 pages: both prompts fit (2+1 pages each would overflow), so
    # both admit, then growth runs the pool dry mid-stream
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=P + G,
                                         paged=True, page_size=8,
                                         n_pages=4))
    reqs = [Request(rid=i, tokens=rng.integers(
                0, cfg.vocab, (P,)).astype(np.int32), gen=G)
            for i in range(2)]
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    assert set(out) == {0, 1}
    assert all(len(out[i]) == G for i in range(2))
    assert sched.stats["preempted"] > 0
    assert sched.allocator.free_pages == eng.n_pages
    # greedy streams still match solo runs (no near-ties with random
    # params, so recompute preemption reproduces the same tokens)
    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=P + G),
                        params=eng.params)
    for r in reqs:
        want, _ = solo.generate(
            {"tokens": jnp.asarray(r.tokens)[None]}, gen=r.gen)
        np.testing.assert_array_equal(out[r.rid], np.asarray(want[0]),
                                      err_msg=f"request {r.rid}")


def test_scheduler_audio_encoder_longer_than_decoder_budget(rng):
    """Regression: the scheduler sized the cross-attention cache to
    the DECODER max_len, so encoder frame counts above it (the normal
    speech regime) crashed at admission.  With an explicit enc_len the
    stream runs and matches solo generation; an over-budget frontend
    raises a clear error instead of a negative-pad crash."""
    cfg = _audio_cfg()
    P, G, F = 4, 4, 40                  # 40 encoder frames >> max_len
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=P + G,
                                         paged=True, page_size=4))
    sched = Scheduler(eng, enc_len=F)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab, (P,)).astype(
                        np.int32),
                    gen=G,
                    frontend_emb=rng.standard_normal(
                        (F, cfg.frontend_dim)).astype(np.float32))
            for i in range(2)]
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=P + G),
                        params=eng.params)
    for r in reqs:
        want, _ = solo.generate(
            {"tokens": jnp.asarray(r.tokens)[None],
             "frontend_emb": jnp.asarray(r.frontend_emb)[None]},
            gen=r.gen)
        np.testing.assert_array_equal(out[r.rid], np.asarray(want[0]),
                                      err_msg=f"request {r.rid}")

    over = Scheduler(eng, enc_len=8)
    over.submit(reqs[0])
    res = over.run()[reqs[0].rid]       # rejected, not raised
    assert res.status is RequestStatus.REJECTED
    assert "encoder frames exceed" in res.error


def test_page_allocator_invariants():
    al = PageAllocator(4)
    a = al.alloc(3)
    assert al.free_pages == 1 and al.used_pages == 3
    with pytest.raises(PagePoolExhausted, match="exhausted"):
        al.alloc(2)
    al.free(a[:2])
    assert al.free_pages == 3
    with pytest.raises(ValueError, match="double free"):
        al.free([a[0]])
    with pytest.raises(ValueError, match="invalid page"):
        al.free([99])


# ------------------------------------------------- RNG regression


def test_sampled_decode_adjacent_seeds_decorrelate(rng):
    """Regression: the old per-step key PRNGKey(seed + i) collides
    across requests — seed s at step i and seed s+1 at step i-1 sample
    with the IDENTICAL key, correlating adjacent-seed token streams in
    a serving fleet.  The fold_in derivation must (a) give every
    (seed, step) pair a distinct key and (b) be what ``generate``
    actually samples with, deterministically."""
    # (a) no key collisions across a (seed, step) grid — the old
    # scheme collides wherever seed + step is equal
    keys = {}
    for seed in range(4):
        for step in range(8):
            k = tuple(np.asarray(jax.random.key_data(
                jax.random.fold_in(jax.random.PRNGKey(seed), step)))
                .ravel().tolist())
            assert k not in keys, \
                f"key collision: {(seed, step)} vs {keys[k]}"
            keys[k] = (seed, step)

    # (b) generate's sampled stream replays with fold_in keys...
    cfg = _cfg(vocab=64)
    B, P, G, seed = 1, 4, 8, 5
    eng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G))
    toks = jnp.asarray(rng.integers(2, cfg.vocab, (B, P)), jnp.int32)
    got, _ = eng.generate({"tokens": toks}, gen=G, temperature=1.0,
                          seed=seed)

    def replay(step_key):
        logits, cache = eng.prefill({"tokens": toks})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [tok]
        for i in range(G - 1):
            logits, cache = eng.decode_step(tok, P + i, cache)
            tok = jax.random.categorical(
                step_key(i), logits, -1).astype(jnp.int32)
            out.append(tok)
        return np.asarray(jnp.stack(out, 1))

    base = jax.random.PRNGKey(seed)
    np.testing.assert_array_equal(
        np.asarray(got), replay(lambda i: jax.random.fold_in(base, i)))
    # ...and NOT with the colliding additive-seed keys (a revert to
    # PRNGKey(seed + i) flips this stream)
    assert not np.array_equal(
        np.asarray(got),
        replay(lambda i: jax.random.PRNGKey(seed + i)))
    # determinism: same (seed, args) -> same tokens on replay
    got2, _ = eng.generate({"tokens": toks}, gen=G, temperature=1.0,
                           seed=seed)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
