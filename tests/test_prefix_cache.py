"""Prefix-sharing radix cache tests: refcounted allocator semantics,
trie match/insert/evict boundaries (whole-page granularity off-by-ones),
the device-side copy-on-write page fork (incl. int8 scale sidecars),
scheduler integration (suffix-only prefill bit-identity vs the
cache-off scheduler for every paged family x kv dtype), the
shared-page double-free regression, eviction-before-preemption
ordering, and a deterministic randomized interleaving pinning the
refcount partition invariant (the hypothesis mirror lives in
tests/test_resilience_prop.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MLAConfig, ModelConfig, MoEConfig
from repro.engine import (DecodeEngine, EngineConfig, PageAllocator,
                          PrefixCache, Request, Scheduler, fork_page)

PS = 4          # page_size used throughout


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


_MLA = MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                 nope_head_dim=16, v_head_dim=16)


def _mla_cfg():
    return _cfg(mla=_MLA)


def _moe_mla_cfg():
    return _cfg(family="moe",
                moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                              first_k_dense=1, d_ff_dense=128,
                              capacity_factor=4.0),
                mla=_MLA)


def _engine(cfg, B=2, P=8, G=5, n_pages=16, **kw):
    return DecodeEngine(cfg, EngineConfig(
        batch=B, max_len=P + G, paged=True, page_size=PS,
        n_pages=n_pages, prefix_cache=True, **kw))


def _run(eng, reqs, *, prefix_cache=None, **sched_kw):
    sched = Scheduler(eng, prefix_cache=prefix_cache, **sched_kw)
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    return sched, out


def _reqs(prompts, gen=5):
    return [Request(rid=i, tokens=np.asarray(p, np.int32), gen=gen,
                    seed=i)
            for i, p in enumerate(prompts)]


# ------------------------------------------------- refcounted allocator


def test_allocator_refcounts():
    al = PageAllocator(4)
    (p,) = al.alloc(1)
    assert al.refcount(p) == 1 and al.shared_pages == 0
    al.incref([p])
    assert al.refcount(p) == 2 and al.shared_pages == 1
    al.decref([p])
    assert al.refcount(p) == 1 and al.used_pages == 1
    al.decref([p])                      # last ref: page returns
    assert al.free_pages == 4 and al.refcount(p) == 0
    al.check()


def test_allocator_free_of_shared_page_raises():
    """The double-free shape the scheduler used to hit: ``free`` on a
    page another holder still references must refuse loudly."""
    al = PageAllocator(4)
    pages = al.alloc(2)
    al.incref(pages)
    with pytest.raises(ValueError, match="shared page"):
        al.free(pages)
    al.decref(pages)
    al.free(pages)                      # sole ref: plain free still works
    assert al.free_pages == 4
    al.check()


def test_allocator_ref_misuse_raises():
    al = PageAllocator(2)
    (p,) = al.alloc(1)
    with pytest.raises(ValueError):
        al.incref([p + 1])              # not handed out
    al.decref([p])
    with pytest.raises(ValueError):
        al.decref([p])                  # over-decref
    al.check()


def test_allocator_decref_duplicates_in_one_call():
    """A caller may hold several refs on one page (trie + slot row) and
    release them in a single decref list."""
    al = PageAllocator(2)
    (p,) = al.alloc(1)
    al.incref([p])
    al.decref([p, p])
    assert al.free_pages == 2
    al.check()


# ------------------------------------------------- trie boundaries


def _insert(al, pc, tokens):
    """Retiring-slot idiom: alloc the whole pages, insert, drop the
    slot refs (the trie keeps what it indexed, the rest frees)."""
    n_whole = len(tokens) // pc.page_size
    pages = al.alloc(n_whole)
    pc.insert(tokens, pages)
    if pages:
        al.decref(pages)
    return pages


@pytest.mark.parametrize("P,want_cached,want_match", [
    (1, 0, 0),           # 1-token prompt: nothing whole to share
    (PS - 1, 0, 0),      # under a page
    (PS, 1, 0),          # exactly one page cached, but matching the
                         # SAME prompt must leave >= 1 suffix token
    (PS + 1, 1, 1),      # one whole page + partial tail
    (2 * PS, 2, 1),      # two whole pages; match capped at len-1
    (2 * PS + 1, 2, 2),
], ids=["one-token", "ps-1", "ps", "ps+1", "2ps", "2ps+1"])
def test_trie_whole_page_boundaries(P, want_cached, want_match):
    """Off-by-ones at the page boundary: only whole pages are indexed,
    and a match never swallows the final token (the suffix prefill must
    produce the first generated token's logits)."""
    al = PageAllocator(16)
    pc = PrefixCache(PS, al)
    tokens = np.arange(P, dtype=np.int32)
    _insert(al, pc, tokens)
    assert pc.cached_pages == want_cached
    assert len(pc.match(tokens)) == want_match
    assert al.used_pages == want_cached     # partial tail pages freed
    pc.check()
    al.check()


def test_trie_match_is_prefix_ordered_and_longest():
    al = PageAllocator(16)
    pc = PrefixCache(PS, al)
    tokens = np.arange(3 * PS, dtype=np.int32)
    pages = al.alloc(3)
    pc.insert(tokens, pages)
    al.decref(pages)
    # longer query: all 3 cached pages come back, in prefix order
    q = np.concatenate([tokens, [99]])
    assert pc.match(q) == pages
    # diverging third page: only the shared 2-page prefix matches
    q2 = np.concatenate([tokens[:2 * PS], [7] * PS, [99]])
    assert pc.match(q2) == pages[:2]
    assert pc.match(np.asarray([5, 6, 7])) == []


def test_trie_dedup_keeps_canonical_page():
    al = PageAllocator(16)
    pc = PrefixCache(PS, al)
    tokens = np.arange(PS, dtype=np.int32)
    (a,) = al.alloc(1)
    assert pc.insert(tokens, [a]) == 1
    (b,) = al.alloc(1)
    assert pc.insert(tokens, [b]) == 0      # duplicate: no new node
    assert pc.match(np.concatenate([tokens, [0]])) == [a]
    assert al.refcount(b) == 1              # duplicate stays caller-owned
    al.decref([a, b])
    pc.check()


def test_trie_evict_lru_and_refcount_safety():
    """Eviction is LRU over refcount-1 leaves and never drops a page a
    slot still holds; emptying a branch cascades to its parent."""
    al = PageAllocator(16)
    pc = PrefixCache(PS, al)
    old = np.asarray([1] * (2 * PS), np.int32)
    new = np.asarray([2] * PS, np.int32)
    old_pages = al.alloc(2)
    pc.insert(old, old_pages)
    al.decref(old_pages)
    new_pages = al.alloc(1)
    pc.insert(new, new_pages)
    al.decref(new_pages)
    # pin the NEW page like a slot would; LRU would prefer old anyway
    al.incref(new_pages)
    assert pc.evict(10) == 2                # both old pages, cascading
    assert al.refcount(new_pages[0]) == 2   # pinned page untouched
    assert pc.cached_pages == 1
    al.decref(new_pages)
    assert pc.evict(10) == 1                # unpinned: now evictable
    assert al.free_pages == 16
    pc.check()
    al.check()


# ------------------------------------------------- device-side CoW fork


@pytest.mark.parametrize("make_cfg,kv_dtype", [
    (_cfg, "bf16"), (_cfg, "int8"), (_mla_cfg, "int8")],
    ids=["gqa", "gqa-int8", "mla-int8"])
def test_fork_page_copies_every_leaf(make_cfg, kv_dtype, rng):
    """``fork_page`` duplicates one physical page across every pool
    leaf — including the fp32 per-page scale sidecar rows of int8
    pools — leaving all other pages untouched."""
    cfg = make_cfg()
    eng = _engine(cfg, kv_dtype=kv_dtype)
    cache = eng.init_paged_cache()
    cache = jax.tree.map(
        lambda leaf: jnp.asarray(
            rng.standard_normal(leaf.shape), leaf.dtype)
        if jnp.issubdtype(leaf.dtype, jnp.floating)
        else jnp.asarray(rng.integers(-5, 5, leaf.shape), leaf.dtype),
        cache)
    src, dst, other = 1, 3, 0
    before = jax.tree.map(lambda leaf: np.asarray(leaf), cache)
    forked = fork_page(cfg, cache, src, dst)
    for (path, leaf), (_, was) in zip(
            jax.tree_util.tree_flatten_with_path(forked)[0],
            jax.tree_util.tree_flatten_with_path(before)[0]):
        got = np.asarray(leaf)
        np.testing.assert_array_equal(got[:, dst], was[:, src],
                                      err_msg=str(path))
        np.testing.assert_array_equal(got[:, other], was[:, other],
                                      err_msg=str(path))
        np.testing.assert_array_equal(got[:, src], was[:, src],
                                      err_msg=str(path))


def test_fork_page_rejects_audio():
    cfg = _cfg(family="audio", enc_layers=2, frontend="audio",
               frontend_dim=24)
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=16, paged=True,
                                         page_size=PS))
    with pytest.raises(ValueError, match="audio"):
        fork_page(cfg, eng.init_paged_cache(), 0, 1)


# ------------------------------------------------- scheduler integration


@pytest.mark.parametrize("make_cfg", [_cfg, _mla_cfg, _moe_mla_cfg],
                         ids=["gqa", "mla", "moe-mla"])
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_prefix_scheduler_matches_off(make_cfg, kv_dtype, rng):
    """Greedy token streams with the prefix cache ON are bit-identical
    to the cache-off scheduler — shared-prompt requests run suffix-only
    prefill over aliased pages.  Exact for model-dtype pools by
    construction; for int8 pools the hit's suffix prefill reads the
    dequantized prefix (cold prefill saw full precision), so identity
    there is pinned empirically at this scale/seed — the per-page
    scales match exactly because the shared pages hold the same
    tokens."""
    cfg = make_cfg()
    P, G = 9, 5
    eng = _engine(cfg, P=P + 3, G=G, kv_dtype=kv_dtype)
    shared = rng.integers(2, cfg.vocab, (P,)).astype(np.int32)
    prompts = [shared, shared,                      # exact repeat
               np.concatenate([shared, [7, 8, 9]]),  # extension
               rng.integers(2, cfg.vocab, (P,))]     # unrelated
    off, want = _run(eng, _reqs(prompts, gen=G), prefix_cache=False)
    on, got = _run(eng, _reqs(prompts, gen=G))
    for i in range(len(prompts)):
        np.testing.assert_array_equal(np.asarray(got[i]),
                                      np.asarray(want[i]),
                                      err_msg=f"req {i}")
    assert on.stats["prefix_hits"] >= 2
    assert on.stats["prefix_hit_tokens"] >= 2 * PS
    assert on.stats["shared_pages"] >= 1
    assert off.stats["prefix_hits"] == 0
    on.prefix.check()
    on.allocator.check()


def test_prefix_scheduler_matches_off_unbucketed(rng):
    """bucket_tables=False stages full-width tables; aliasing must be
    oblivious to the staging width."""
    cfg = _cfg()
    shared = rng.integers(2, cfg.vocab, (9,)).astype(np.int32)
    eng = _engine(cfg, P=9, G=5)
    prompts = [shared, shared]
    _, want = _run(eng, _reqs(prompts), prefix_cache=False,
                   bucket_tables=False)
    on, got = _run(eng, _reqs(prompts), bucket_tables=False)
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(got[i]),
                                      np.asarray(want[i]))
    assert on.stats["prefix_hits"] == 1


def test_multi_turn_retirement_indexes_generated_tokens(rng):
    """A follow-up prompt extending a finished conversation (prompt +
    generated tokens) hits pages covering the GENERATED history too —
    retirement indexes the whole resident sequence, not just the
    prompt."""
    cfg = _cfg()
    P, G = 8, 6
    eng = _engine(cfg, B=2, P=P + G + 4, G=G, n_pages=24)
    prompt = rng.integers(2, cfg.vocab, (P,)).astype(np.int32)
    s1, out1 = _run(eng, [Request(rid=0, tokens=prompt, gen=G, seed=0)])
    turn1 = np.concatenate([prompt, np.asarray(out1[0], np.int32)])
    # reuse the SAME scheduler (the trie persists across run() calls)
    follow = np.concatenate([turn1,
                             rng.integers(2, cfg.vocab, (3,))
                             .astype(np.int32)])
    s1.submit(Request(rid=1, tokens=follow, gen=3, seed=1))
    out2 = s1.run()
    assert out2[1].ok
    # conversation history is P + G - 1 resident positions: every
    # whole page of it must have come from the cache
    assert s1.stats["prefix_hit_tokens"] >= ((P + G - 1) // PS) * PS
    # bit-identity of the follow-up against a cold scheduler
    _, want = _run(eng, [Request(rid=1, tokens=follow, gen=3, seed=1)],
                   prefix_cache=False)
    np.testing.assert_array_equal(np.asarray(out2[1]),
                                  np.asarray(want[1]))


def test_preempting_shared_slot_no_double_free(rng):
    """Regression for the shared-page double-free: two slots alias the
    same prefix pages; preempting one must DECREF (old code free'd),
    leaving the survivor's prefix intact and the allocator coherent."""
    cfg = _cfg()
    P, G = 9, 6
    eng = _engine(cfg, B=2, P=P, G=G, n_pages=24)
    shared = rng.integers(2, cfg.vocab, (P,)).astype(np.int32)
    reqs = _reqs([shared, shared], gen=G)
    _, want = _run(eng, _reqs([shared, shared], gen=G),
                   prefix_cache=False)
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    assert sched.admit() == 2
    assert sched.allocator.shared_pages >= 1
    sched._preempt(1)               # mid-flight eviction of the sharer
    sched.allocator.check()         # old code: free() already corrupted
    sched.prefix.check()
    out = sched.run()               # victim re-admits and finishes
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(out[i]),
                                      np.asarray(want[i]),
                                      err_msg=f"req {i}")
    sched.allocator.check()


def test_cow_guard_forks_shared_write_page(rng):
    """An externally shared WRITE page (snapshot-style incref) is
    forked before the next decode write — the stream's tokens are
    unchanged and the pinned original page is never written through."""
    cfg = _cfg()
    P, G = 9, 6
    eng = _engine(cfg, B=1, P=P, G=G, n_pages=16)
    prompt = rng.integers(2, cfg.vocab, (P,)).astype(np.int32)
    _, want = _run(eng, [Request(rid=0, tokens=prompt, gen=G, seed=0)],
                   prefix_cache=False)
    sched = Scheduler(eng)
    sched.submit(Request(rid=0, tokens=prompt, gen=G, seed=0))
    assert sched.admit() == 1
    slot = sched.slots[0]
    wp = slot.length // sched.page_size
    pinned = slot.pages[wp]
    sched.allocator.incref([pinned])        # external snapshot ref
    snap = np.asarray(jax.tree_util.tree_leaves(sched.cache)[0][:, pinned])
    out = sched.run()
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.asarray(want[0]))
    assert sched.stats["cow_forks"] >= 1
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(sched.cache)[0][:, pinned]),
        snap)                               # pinned page untouched
    assert sched.allocator.refcount(pinned) == 1
    sched.allocator.decref([pinned])
    sched.allocator.check()


def test_eviction_precedes_preemption(rng):
    """Pool pressure reclaims refcount-1 trie leaves BEFORE any active
    slot is preempted: cold cache entries are cheaper than redoing a
    live request's prefill."""
    cfg = _cfg()
    P, G = 8, 6
    # pool sized so the second request cannot fit while the first
    # request's retired pages sit in the trie
    eng = _engine(cfg, B=1, P=P, G=G, n_pages=4)
    r0 = Request(rid=0, tokens=rng.integers(2, cfg.vocab, (P,))
                 .astype(np.int32), gen=G, seed=0)
    r1 = Request(rid=1, tokens=rng.integers(2, cfg.vocab, (P,))
                 .astype(np.int32), gen=G, seed=1)
    sched, out = _run(eng, [r0, r1])
    assert out[0].ok and out[1].ok
    assert sched.stats["prefix_evictions"] >= 1
    assert sched.stats["preempted"] == 0
    sched.allocator.check()


def test_clear_drains_pool(rng):
    """After the stream drains, the only pages still held are the
    trie's; ``clear()`` hands every one back (the chaos-leg leak
    check)."""
    cfg = _cfg()
    eng = _engine(cfg, P=9, G=5)
    shared = rng.integers(2, cfg.vocab, (9,)).astype(np.int32)
    sched, out = _run(eng, _reqs([shared, shared, shared]))
    assert all(v.ok for v in out.values())
    assert sched.allocator.free_pages == \
        eng.n_pages - sched.prefix.cached_pages
    sched.prefix.clear()
    assert sched.allocator.free_pages == eng.n_pages
    sched.allocator.check()


# ------------------------------------------------- randomized interleaving


def test_refcount_partition_under_random_interleaving():
    """Deterministic mirror of the hypothesis property (which skips
    when hypothesis is absent): random insert / match+incref / release
    / evict interleavings keep the refcount partition exact — every
    owned page's refcount equals (trie nodes owning it) + (outstanding
    match holds on it) — and eviction never frees a held page."""
    rng = np.random.default_rng(0)
    al = PageAllocator(12)
    pc = PrefixCache(PS, al)
    holds = []

    def trie_counts():
        counts = {}
        stack = list(pc._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            counts[nd.page] = counts.get(nd.page, 0) + 1
        return counts

    def partition():
        counts = trie_counts()
        for pages in holds:
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        assert set(counts) == {
            p for p in range(al.n_pages) if al.refcount(p) > 0}
        for p, want in counts.items():
            assert al.refcount(p) == want, f"page {p}"
        al.check()
        pc.check()

    for _ in range(400):
        op = rng.integers(0, 4)
        toks = rng.integers(0, 2, (int(rng.integers(1, 3 * PS + 2)),))
        if op == 0:                                  # retiring insert
            n_whole = len(toks) // PS
            if n_whole <= al.free_pages:
                pages = al.alloc(n_whole)
                pc.insert(toks, pages)
                if pages:
                    al.decref(pages)
        elif op == 1:                                # match + hold
            pages = pc.match(toks)
            if pages:
                al.incref(pages)
                holds.append(pages)
        elif op == 2 and holds:                      # release a hold
            al.decref(holds.pop(int(rng.integers(len(holds)))))
        elif op == 3:                                # evict
            held = {p for hold in holds for p in hold}
            pc.evict(int(rng.integers(1, 4)))
            for p in held:
                assert al.refcount(p) >= 1, "evicted a held page"
        partition()
    for pages in holds:
        al.decref(pages)
    pc.clear()
    assert al.free_pages == al.n_pages
