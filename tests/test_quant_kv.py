"""Quantized KV page pool tests: the shared symmetric-int8 helper, the
q8 decode-partial ops (dense + paged, GQA + split-operand MLA, xla +
pallas), quantize-on-write (prefill scatter and the per-step decode
page write), and the engine/scheduler plumbing — greedy q8 token
streams pinned to the bf16 engine, with bounded logit drift."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MLAConfig, ModelConfig, MoEConfig
from repro.engine import DecodeEngine, EngineConfig, Request, Scheduler
from repro.engine import paged_cache as PC
from repro.kernels import dispatch as D
from repro.kernels.quant import (QEPS, dequantize_int8, int8_scale,
                                 quantize_int8)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


def _mla_cfg():
    return _cfg(mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              rope_head_dim=8, nope_head_dim=16,
                              v_head_dim=16))


def _moe_mla_cfg():
    return _cfg(family="moe",
                moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                              first_k_dense=1, d_ff_dense=128,
                              capacity_factor=4.0),
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              rope_head_dim=8, nope_head_dim=16,
                              v_head_dim=16))


# ------------------------------------------------- quant helper


def test_quantize_int8_roundtrip_and_symmetry():
    x = jax.random.normal(KEY, (4, 32, 2, 16)) * 3.0
    q, s = quantize_int8(x, axis=(1, 3))
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == (4, 1, 2, 1)          # keepdims: broadcasts back
    # roundtrip error within half a quantization step per group
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s.max()) / 2 + 1e-7
    # symmetric grid: q(x) == -q(-x) exactly
    qn, sn = quantize_int8(-x, axis=(1, 3))
    np.testing.assert_array_equal(np.asarray(qn), -np.asarray(q))
    np.testing.assert_array_equal(np.asarray(sn), np.asarray(s))


def test_quantize_int8_all_zero_group_is_safe():
    """The eps floor keeps all-zero groups finite and exact."""
    np.testing.assert_allclose(float(int8_scale(jnp.float32(0.0))),
                               QEPS / 127.0, rtol=1e-6)
    q, s = quantize_int8(jnp.zeros((2, 8)), axis=1)
    assert np.isfinite(np.asarray(s)).all()
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)), 0.0)


def test_compression_shares_quant_helper():
    """dist.compression consumes the same int8 recipe (one idiom for
    wire payloads and KV pages)."""
    from repro.dist import compression
    assert compression.quantize_int8 is quantize_int8


# ------------------------------------------------- q8 op contracts


def _quant_cache(k, v):
    """(B,T,KV,Dh) caches -> int8 + per-(B,KV) fp32 scales."""
    B, _, KV, _ = k.shape
    kq, ks = quantize_int8(k, axis=(1, 3))
    vq, vs = quantize_int8(v, axis=(1, 3))
    return kq, vq, ks.reshape(B, KV), vs.reshape(B, KV)


def _quant_pools(kp, vp):
    """(n_pages,ps,KV,Dh) pools -> int8 + per-(page,KV) fp32 scales."""
    n_pages, _, KV, _ = kp.shape
    kq, ks = quantize_int8(kp, axis=(1, 3))
    vq, vs = quantize_int8(vp, axis=(1, 3))
    return kq, vq, ks.reshape(n_pages, KV), vs.reshape(n_pages, KV)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_q8_dense_op_matches_dequantized_reference(backend):
    """decode_partial_q8 == decode_partial run on the dequantized
    cache: the in-kernel scale hoist is exact, not approximate."""
    B, T, KV, Dh, H = 2, 64, 2, 16, 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    k = jax.random.normal(ks[1], (B, T, KV, Dh))
    v = jax.random.normal(ks[2], (B, T, KV, Dh))
    kq, vq, ksc, vsc = _quant_cache(k, v)
    kf = kq.astype(jnp.float32) * ksc[:, None, :, None]
    vf = vq.astype(jnp.float32) * vsc[:, None, :, None]
    cur = jnp.int32(50)
    want = D.dispatch("decode_partial", "xla", q, kf, vf, cur)
    got = D.dispatch("decode_partial_q8", backend, q, kq, vq, ksc, vsc,
                     cur)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_q8_paged_op_matches_dequantized_reference(backend):
    B, KV, Dh, H, ps, J, n_pages = 2, 2, 16, 4, 4, 6, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    kp = jax.random.normal(ks[1], (n_pages, ps, KV, Dh))
    vp = jax.random.normal(ks[2], (n_pages, ps, KV, Dh))
    kq, vq, ksc, vsc = _quant_pools(kp, vp)
    table = jnp.asarray(np.random.default_rng(0).permutation(n_pages)
                        [:B * J].reshape(B, J), jnp.int32)
    lens = jnp.array([13, 21], jnp.int32)
    counts = jnp.clip(lens[:, None] - jnp.arange(J)[None, :] * ps,
                      0, ps).astype(jnp.int32)
    kf = kq.astype(jnp.float32) * ksc[:, None, :, None]
    vf = vq.astype(jnp.float32) * vsc[:, None, :, None]
    want = D.dispatch("decode_partial_paged", "xla", q, kf, vf, table,
                      counts)
    got = D.dispatch("decode_partial_paged_q8", backend, q, kq, vq,
                     ksc, vsc, table, counts)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_q8_mla_ops_match_dequantized_reference(backend):
    """Split-operand MLA q8 (latent + rope quantized independently,
    per-page/per-row scales) against the dequantized split ops — dense
    cache and paged pool forms."""
    B, H, r, rope, T = 2, 4, 16, 8, 64
    scale = 1.0 / (24 ** 0.5)
    ks = jax.random.split(KEY, 4)
    q_abs = jax.random.normal(ks[0], (B, H, r))
    q_rope = jax.random.normal(ks[1], (B, H, rope))
    ckv = jax.random.normal(ks[2], (B, T, r))
    krope = jax.random.normal(ks[3], (B, T, rope))
    cq, cs = quantize_int8(ckv, axis=(1, 2))
    rq, rs = quantize_int8(krope, axis=(1, 2))
    cs, rs = cs.reshape(B), rs.reshape(B)
    cur = jnp.int32(50)
    want = D.dispatch("decode_partial_mla", "xla", q_abs, q_rope,
                      cq.astype(jnp.float32) * cs[:, None, None],
                      rq.astype(jnp.float32) * rs[:, None, None],
                      cur, scale=scale)
    got = D.dispatch("decode_partial_mla_q8", backend, q_abs, q_rope,
                     cq, rq, cs, rs, cur, scale=scale)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)

    # paged: per-page scales over the pooled latents
    ps, J, n_pages = 4, 6, 16
    ckv_pool = jax.random.normal(ks[2], (n_pages, ps, r))
    krope_pool = jax.random.normal(ks[3], (n_pages, ps, rope))
    cq, cs = quantize_int8(ckv_pool, axis=(1, 2))
    rq, rs = quantize_int8(krope_pool, axis=(1, 2))
    cs, rs = cs.reshape(n_pages), rs.reshape(n_pages)
    table = jnp.asarray(np.random.default_rng(0).permutation(n_pages)
                        [:B * J].reshape(B, J), jnp.int32)
    counts = jnp.clip(jnp.array([13, 21])[:, None]
                      - jnp.arange(J)[None, :] * ps, 0, ps)
    counts = counts.astype(jnp.int32)
    want = D.dispatch("decode_partial_mla_paged", "xla", q_abs, q_rope,
                      cq.astype(jnp.float32) * cs[:, None, None],
                      rq.astype(jnp.float32) * rs[:, None, None],
                      table, counts, scale=scale)
    got = D.dispatch("decode_partial_mla_paged_q8", backend, q_abs,
                     q_rope, cq, rq, cs, rs, table, counts, scale=scale)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_q8_attend_drift_vs_unquantized_is_bounded():
    """Against the UNquantized cache the q8 attend output drifts by the
    quantization error only — small and bounded, and nonzero (the q8
    path really is engaged)."""
    from repro.dist.decode import local_decode_attend
    B, T, KV, Dh, H = 2, 64, 2, 16, 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    k = jax.random.normal(ks[1], (B, T, KV, Dh))
    v = jax.random.normal(ks[2], (B, T, KV, Dh))
    kq, vq, ksc, vsc = _quant_cache(k, v)
    cur = jnp.int32(50)
    want = local_decode_attend(q, k, v, cur)
    got = local_decode_attend(q, kq, vq, cur, k_scale=ksc, v_scale=vsc)
    drift = float(jnp.abs(got - want).max())
    assert 0.0 < drift < 0.05, drift


# ------------------------------------------------- quantize-on-write


def test_prefill_scatter_q8_roundtrip_error_bounds():
    """_scatter_pages_q8 quantizes per page (per head for GQA): the
    dequantized pages reproduce the prefill KV within half a step of
    each page's own scale, and the partial last page's padding lands as
    exact zeros (the scrub)."""
    L, B, S, KV, Dh, ps, n_pages = 2, 2, 10, 2, 8, 4, 12
    kv = jax.random.normal(KEY, (L, B, S, KV, Dh)) * 2.0
    J = -(-S // ps)
    table = jnp.asarray([[0, 1, 9], [4, 3, 7]], jnp.int32)
    pool = jnp.zeros((L, n_pages, ps, KV, Dh), jnp.int8)
    scales = jnp.zeros((L, n_pages, KV), jnp.float32)
    pool, scales = PC._scatter_pages_q8(pool, scales, kv, table)

    got = (pool[:, table[:, :J]].astype(jnp.float32)
           * scales[:, table[:, :J]][:, :, :, None, :, None])
    got = got.reshape(L, B, J * ps, KV, Dh)
    err = jnp.abs(got[:, :, :S] - kv)
    step = scales[:, table[:, :J]].max()
    assert float(err.max()) <= float(step) / 2 + 1e-7
    # pad rows of the partial page are exact zeros
    np.testing.assert_array_equal(np.asarray(got[:, :, S:]), 0.0)


def test_quantized_page_write_fresh_reset_and_growth():
    """The decode-step page write: offset 0 resets the scale and scrubs
    the reused page; later writes grow the scale monotonically and
    requantize resident rows onto the new grid; inactive slots (page id
    == n_pages) are dropped."""
    n_pages, ps, KV, Dh = 4, 4, 2, 8
    pool = jnp.full((n_pages, ps, KV, Dh), 55, jnp.int8)  # stale bytes
    scales = jnp.full((n_pages, KV), 9.9, jnp.float32)    # stale scales
    x0 = jax.random.normal(KEY, (1, KV, Dh))
    pages = jnp.array([2], jnp.int32)

    # fresh page: scale reset to the token's amax, rest of page zeroed
    pool, scales = PC.quantized_page_write(
        pool, scales, pages, jnp.array([0], jnp.int32), x0)
    s0 = np.asarray(int8_scale(jnp.max(jnp.abs(x0), axis=-1))[0])
    np.testing.assert_allclose(np.asarray(scales[2]), s0, rtol=1e-6)
    row0 = np.asarray(pool[2, 0].astype(jnp.float32)
                      * scales[2][:, None])
    np.testing.assert_allclose(row0, np.asarray(x0[0]),
                               atol=float(s0.max()) / 2 + 1e-7)
    np.testing.assert_array_equal(np.asarray(pool[2, 1:]), 0)

    # growth: a larger token raises the scale; the resident row is
    # requantized onto the new grid and stays within its half-step
    x1 = 4.0 * jax.random.normal(jax.random.PRNGKey(1), (1, KV, Dh))
    pool, scales = PC.quantized_page_write(
        pool, scales, pages, jnp.array([1], jnp.int32), x1)
    s1 = np.asarray(scales[2])
    assert (s1 >= s0 - 1e-9).all()
    row0 = np.asarray(pool[2, 0].astype(jnp.float32)
                      * scales[2][:, None])
    np.testing.assert_allclose(row0, np.asarray(x0[0]),
                               atol=float(s1.max()) + 1e-7)

    # a smaller token never shrinks the scale (monotone while filling)
    pool, scales = PC.quantized_page_write(
        pool, scales, pages, jnp.array([2], jnp.int32), 0.01 * x0)
    np.testing.assert_allclose(np.asarray(scales[2]), s1, rtol=1e-6)

    # inactive slot: page id n_pages drops the write entirely
    before = np.asarray(pool), np.asarray(scales)
    pool, scales = PC.quantized_page_write(
        pool, scales, jnp.array([n_pages], jnp.int32),
        jnp.array([0], jnp.int32), x0)
    np.testing.assert_array_equal(np.asarray(pool), before[0])
    np.testing.assert_array_equal(np.asarray(scales), before[1])


def test_paged_cache_spec_q8_layout():
    """int8 pools + fp32 sidecars with the layer axis leading (the
    _scan_stack per-layer slicing contract); bf16 spec is unchanged."""
    cfg = _cfg()
    spec = PC.paged_cache_spec(cfg, 8, 4, 2, kv_dtype="int8")
    assert spec["k"].dtype == jnp.int8
    assert spec["k_scale"].shape == (cfg.n_layers, 8, cfg.n_kv_heads)
    assert spec["k_scale"].dtype == jnp.float32
    mspec = PC.paged_cache_spec(_mla_cfg(), 8, 4, 2, kv_dtype="int8")
    assert mspec["ckv"].dtype == jnp.int8
    assert mspec["ckv_scale"].shape == (cfg.n_layers, 8)
    assert mspec["krope_scale"].shape == (cfg.n_layers, 8)
    base = PC.paged_cache_spec(cfg, 8, 4, 2)
    assert "k_scale" not in base
    assert base["k"].dtype == jnp.dtype(cfg.dtype)
    with pytest.raises(ValueError, match="kv_dtype"):
        PC.paged_cache_spec(cfg, 8, 4, 2, kv_dtype="fp8")
    with pytest.raises(ValueError, match="audio"):
        PC.paged_cache_spec(_cfg(family="audio", enc_layers=2,
                                 frontend="audio", frontend_dim=24),
                            8, 4, 2, enc_len=8, kv_dtype="int8")


# ------------------------------------------------- engine + scheduler


def _engines(cfg, B=2, P=8, G=6, page_size=4):
    """(bf16 paged engine, int8 paged engine) sharing one param tree."""
    bf16 = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                          paged=True,
                                          page_size=page_size))
    q8 = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                        paged=True, page_size=page_size,
                                        kv_dtype="int8"),
                      params=bf16.params)
    return bf16, q8


@pytest.mark.parametrize("make_cfg", [_cfg, _mla_cfg, _moe_mla_cfg],
                         ids=["gqa", "mla", "moe-mla"])
def test_engine_greedy_q8_matches_bf16(make_cfg, rng):
    """Greedy decode with int8 page pools is token-for-token identical
    to the bf16 paged engine on short prompts, and the prefill logits
    drift only within the quantization error bound."""
    cfg = make_cfg()
    B, P, G = 2, 8, 6
    bf16, q8 = _engines(cfg, B=B, P=P, G=G)
    batch = {"tokens": jnp.asarray(rng.integers(2, cfg.vocab, (B, P)),
                                   jnp.int32)}
    want, _ = bf16.generate(batch, gen=G)
    got, _ = q8.generate(batch, gen=G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    lw, cache_w = bf16.prefill(batch)
    lg, cache_g = q8.prefill(batch)
    drift = float(jnp.abs(lg - lw).max())
    assert drift < 0.1, drift
    # decode-step logits (through the quantized page write) drift too,
    # but stay bounded
    tok = jnp.argmax(lw, -1).astype(jnp.int32)
    lens = jnp.full((B,), P, jnp.int32)
    tbl = bf16.default_block_table()
    lw2, _ = bf16.decode_step(tok, lens, cache_w, tbl)
    lg2, _ = q8.decode_step(tok, lens, cache_g, tbl)
    assert float(jnp.abs(lg2 - lw2).max()) < 0.25


def test_engine_q8_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="requires paged"):
        DecodeEngine(cfg, EngineConfig(batch=1, max_len=8,
                                       kv_dtype="int8"))
    with pytest.raises(ValueError, match="kv_dtype"):
        DecodeEngine(cfg, EngineConfig(batch=1, max_len=8, paged=True,
                                       page_size=4, kv_dtype="fp8"))
    audio = _cfg(family="audio", enc_layers=2, frontend="audio",
                 frontend_dim=24)
    with pytest.raises(ValueError, match="audio"):
        DecodeEngine(audio, EngineConfig(batch=1, max_len=8, paged=True,
                                         page_size=4, kv_dtype="int8"))


def test_scheduler_q8_stream_slot_reuse(rng):
    """Continuous batching over int8 pools: 3 requests over 2 slots —
    page/slot reuse goes through the offset-0 scale reset, and every
    stream matches a solo q8 engine run."""
    cfg = _cfg()
    P = 8
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=P + 8,
                                         paged=True, page_size=4,
                                         n_pages=10, kv_dtype="int8"))
    reqs = [Request(rid=i, tokens=rng.integers(
                0, cfg.vocab, (P,)).astype(np.int32), gen=g)
            for i, g in enumerate((3, 7, 5))]
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    assert set(out) == {0, 1, 2}
    assert sched.stats["prefills"] == 3
    assert sched.allocator.free_pages == eng.n_pages

    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=P + 8,
                                          paged=True, page_size=4,
                                          kv_dtype="int8"),
                        params=eng.params)
    for r in reqs:
        want, _ = solo.generate(
            {"tokens": jnp.asarray(r.tokens)[None]}, gen=r.gen)
        np.testing.assert_array_equal(out[r.rid], np.asarray(want[0]),
                                      err_msg=f"request {r.rid}")
