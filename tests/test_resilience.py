"""Fault-tolerant serving tests: request lifecycle (reject / cancel /
deadline / quarantine / park), deterministic fault injection
(``engine.faults``) against the paged scheduler, allocator invariants
under random op sequences (hypothesis), and the resilience-runtime
wiring (retry policy, straggler monitor, heartbeat, latency
percentiles).

The load-bearing property, pinned under EVERY injected fault: the
stream completes, unaffected requests finish with token streams
bit-identical to a fault-free run, and affected requests end in a
terminal status with a reason."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.engine import (DecodeEngine, EngineConfig, Request,
                          RequestResult, RequestStatus, Scheduler)
from repro.engine import faults as F
from repro.engine.paged_cache import PageAllocator, PagePoolExhausted
from repro.runtime.resilience import (Heartbeat, RetryPolicy,
                                      StragglerMonitor, call_with_retries,
                                      percentiles)

P, G = 8, 6


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def eng():
    return DecodeEngine(_cfg(), EngineConfig(batch=2, max_len=16,
                                             paged=True, page_size=4,
                                             n_pages=8))


def _reqs(cfg, gens=(G, G, 4), **kw):
    rng = np.random.default_rng(7)
    return [Request(rid=i, tokens=rng.integers(
                2, cfg.vocab, (P,)).astype(np.int32), gen=g, **kw)
            for i, g in enumerate(gens)]


def _run(eng, reqs, **sched_kw):
    sched = Scheduler(eng, **sched_kw)
    for r in reqs:
        sched.submit(r)
    return sched.run(), sched


@pytest.fixture(scope="module")
def baseline(eng):
    """Fault-free streams for the standard 3-request set (pinned
    bit-identical against solo generate by tests/test_paged.py)."""
    out, _ = _run(eng, _reqs(eng.cfg))
    return {rid: np.asarray(res) for rid, res in out.items()}


def _drained(sched, eng):
    assert sched.allocator.free_pages == eng.n_pages
    sched.allocator.check()


# ------------------------------------------------- injected faults


def test_nan_logits_quarantine_only_affected_slot(eng, baseline):
    """A NaN logit row FAILs exactly the slot that produced it (partial
    tokens + reason attached); every surviving stream is bit-identical
    to the fault-free run."""
    reqs = _reqs(eng.cfg)
    sched = Scheduler(eng)
    proxy = F.inject(sched,
                     decode_faults=[F.NonFiniteLogits(step=2, slot=0)])
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    assert proxy.decode_fn.injected == 1
    # rid 0 sat in slot 0: failed at decode step 2 with the 3 tokens it
    # had — a bit-identical PREFIX of its fault-free stream
    assert out[0].status is RequestStatus.FAILED
    assert "non-finite" in out[0].error
    np.testing.assert_array_equal(out[0], baseline[0][:3])
    assert sched.stats["failed"] == 1
    # survivors bit-identical end to end (rid 2 reuses the freed slot)
    for rid in (1, 2):
        assert out[rid].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(out[rid], baseline[rid])
    _drained(sched, eng)


def test_inf_logits_also_quarantined(eng):
    reqs = _reqs(eng.cfg, gens=(G,))
    sched = Scheduler(eng)
    F.inject(sched, decode_faults=[
        F.NonFiniteLogits(step=1, slot=0, value=float("inf"))])
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    assert out[0].status is RequestStatus.FAILED
    _drained(sched, eng)


def test_transient_step_exception_retried_bit_identical(eng, baseline):
    """One injected step exception is retried (bounded, with backoff)
    and the whole stream is bit-identical to the fault-free run."""
    reqs = _reqs(eng.cfg)
    sched = Scheduler(eng, retry=RetryPolicy(max_retries=2,
                                             backoff_s=0.0))
    F.inject(sched, decode_faults=[F.TransientError(step=1)])
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    assert sched.stats["step_retries"] == 1
    for rid, want in baseline.items():
        assert out[rid].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(out[rid], want)
    _drained(sched, eng)


def test_persistent_step_fault_exhausts_retries(eng):
    """A fault that survives the whole retry budget is NOT request-
    level: it must surface to the caller, not be swallowed."""
    reqs = _reqs(eng.cfg, gens=(G,))
    sched = Scheduler(eng, retry=RetryPolicy(max_retries=2,
                                             backoff_s=0.0))
    F.inject(sched, decode_faults=[F.TransientError(step=1, count=50)])
    for r in reqs:
        sched.submit(r)
    with pytest.raises(F.InjectedFault):
        sched.run()
    assert sched.stats["step_retries"] == 2


def test_prefill_fault_fails_request_not_stream(eng, baseline):
    """A persistent prefill fault FAILs that request alone (its pages
    go back); the requests around it stream bit-identically."""
    reqs = _reqs(eng.cfg)
    sched = Scheduler(eng, retry=RetryPolicy(max_retries=2,
                                             backoff_s=0.0))
    # prefill call 0 = rid 0; calls 1..3 = rid 1's three attempts
    F.inject(sched, prefill_faults=[F.TransientError(step=1, count=3)])
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    assert out[1].status is RequestStatus.FAILED
    assert "prefill failed" in out[1].error
    assert len(out[1]) == 0
    assert sched.stats["prefill_retries"] == 2
    for rid in (0, 2):
        assert out[rid].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(out[rid], baseline[rid])
    _drained(sched, eng)


def test_pool_pressure_serializes_and_completes(eng, baseline):
    """Artificial pool pressure (half the pages held) degrades to
    serialized admission — everything still completes bit-identically
    and the held pages come back on release."""
    reqs = _reqs(eng.cfg)
    sched = Scheduler(eng)
    release = F.hold_pages(sched, 4)
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    for rid, want in baseline.items():
        assert out[rid].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(out[rid], want)
    # at most one request's pages fit beside the held 4
    assert sched.stats["peak_pages"] <= 8
    assert sched.allocator.free_pages == eng.n_pages - 4
    release()
    release()                           # idempotent
    _drained(sched, eng)


def test_over_budget_request_rejected_mid_stream(eng, baseline):
    """An over-budget prompt mixed into a live stream is REJECTED alone
    (used to raise ValueError out of admit(), killing every in-flight
    request); the well-formed requests stream bit-identically."""
    cfg = eng.cfg
    reqs = _reqs(cfg)
    rng = np.random.default_rng(3)
    bad = Request(rid="bad", tokens=rng.integers(
        2, cfg.vocab, (P,)).astype(np.int32), gen=64)  # >> max_len
    order = [reqs[0], bad, reqs[1], reqs[2]]
    out, sched = _run(eng, order)
    assert out["bad"].status is RequestStatus.REJECTED
    assert "exceeds engine max_len" in out["bad"].error
    assert sched.stats["rejected"] == 1
    for rid, want in baseline.items():
        assert out[rid].status is RequestStatus.FINISHED
        np.testing.assert_array_equal(out[rid], want)
    _drained(sched, eng)


# ------------------------------------------------- lifecycle


def test_cancel_pending_and_mid_flight(eng, baseline):
    reqs = _reqs(eng.cfg)
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    sched.admit()                       # rids 0, 1 take the slots
    assert sched.cancel(2)              # still queued
    assert sched.finished[2].status is RequestStatus.CANCELLED
    assert "pending" in sched.finished[2].error
    assert len(sched.finished[2]) == 0
    sched.step()
    sched.step()
    assert sched.cancel(1)              # mid-flight: slot + pages free
    res = sched.finished[1]
    assert res.status is RequestStatus.CANCELLED
    np.testing.assert_array_equal(res, baseline[1][:3])
    assert not sched.cancel(1)          # already terminal
    assert not sched.cancel("nope")     # unknown rid
    out = sched.run()
    assert out[0].status is RequestStatus.FINISHED
    np.testing.assert_array_equal(out[0], baseline[0])
    assert sched.stats["cancelled"] == 2
    _drained(sched, eng)


def test_deadline_while_queued_times_out_without_prefill(eng):
    reqs = _reqs(eng.cfg, gens=(G,))
    reqs[0].deadline_s = 1e-9
    out, sched = _run(eng, reqs)
    assert out[0].status is RequestStatus.TIMED_OUT
    assert "while queued" in out[0].error
    assert sched.stats["prefills"] == 0
    _drained(sched, eng)


def test_max_steps_bounds_a_request(eng, baseline):
    """max_steps is the deterministic deadline: the request ends
    TIMED_OUT with exactly prefill-token + max_steps tokens — a
    bit-identical prefix — while its neighbor runs to completion."""
    reqs = _reqs(eng.cfg, gens=(G, G))
    reqs[0].max_steps = 2
    out, sched = _run(eng, reqs)
    assert out[0].status is RequestStatus.TIMED_OUT
    assert "max_steps" in out[0].error
    np.testing.assert_array_equal(out[0], baseline[0][:3])
    assert out[1].status is RequestStatus.FINISHED
    np.testing.assert_array_equal(out[1], baseline[1])
    assert sched.stats["timed_out"] == 1
    _drained(sched, eng)


def test_wall_deadline_mid_flight(eng):
    """A slow injected step blows through the wall deadline: the
    request ends TIMED_OUT mid-flight with partial tokens."""
    reqs = _reqs(eng.cfg, gens=(G,))
    reqs[0].deadline_s = 0.15
    sched = Scheduler(eng)
    F.inject(sched, decode_faults=[F.SlowStep(step=1, delay_s=0.5)])
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    assert out[0].status is RequestStatus.TIMED_OUT
    assert len(out[0]) < G
    _drained(sched, eng)


def test_status_machine_and_result_surface(eng):
    req = _reqs(eng.cfg, gens=(3,))[0]
    assert req.status is RequestStatus.PENDING
    sched = Scheduler(eng)
    sched.submit(req)
    sched.admit()
    assert req.status is RequestStatus.RUNNING
    out = sched.run()
    assert req.status is RequestStatus.FINISHED
    res = out[req.rid]
    assert isinstance(res, RequestResult) and res.ok
    assert res.error is None
    assert res.latency_s is not None and res.latency_s >= 0
    assert isinstance(res.tokens, np.ndarray)
    assert "FINISHED" in repr(res)
    # slicing keeps the metadata (ndarray-view semantics)
    assert res[:2].status is RequestStatus.FINISHED
    pcts = sched.latency_percentiles()
    assert set(pcts) == {"p50", "p90", "p99"}


def test_preemption_livelock_watchdog_parks(rng):
    """The thrash scenario: two long requests over a pool that fits
    only one.  With max_preemptions=0 the first eviction PARKS the
    victim (no admit→preempt churn); it re-admits once the pool quiets
    and both streams complete bit-identically to solo runs."""
    cfg = _cfg()
    p, g = 8, 16
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=p + g,
                                         paged=True, page_size=8,
                                         n_pages=4))
    reqs = [Request(rid=i, tokens=rng.integers(
                0, cfg.vocab, (p,)).astype(np.int32), gen=g)
            for i in range(2)]
    out, sched = _run(eng, reqs, max_preemptions=0)
    assert sched.stats["parked"] > 0
    assert sched.stats["preempted"] > 0
    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=p + g),
                        params=eng.params)
    for r in reqs:
        assert out[r.rid].status is RequestStatus.FINISHED
        want, _ = solo.generate(
            {"tokens": jnp.asarray(r.tokens)[None]}, gen=r.gen)
        np.testing.assert_array_equal(out[r.rid], np.asarray(want[0]),
                                      err_msg=f"request {r.rid}")
    _drained(sched, eng)


# ------------------------------------------------- monitors


def test_straggler_flag_and_heartbeat(eng, tmp_path):
    hb_path = str(tmp_path / "hb.json")
    reqs = _reqs(eng.cfg, gens=(G, G))
    sched = Scheduler(
        eng,
        straggler=StragglerMonitor(window=16, threshold=3.0, warmup=2),
        heartbeat=Heartbeat(hb_path, interval_s=0.0))
    F.inject(sched, decode_faults=[F.SlowStep(step=4, delay_s=0.75)])
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert sched.stats["straggler_flags"] >= 1
    with open(hb_path) as f:
        beat = json.load(f)
    assert beat["step"] == sched.stats["steps"]
    assert {"active", "pending", "finished", "failed"} <= set(beat)


def test_generate_check_finite(eng):
    from repro.engine.faults import NonFiniteLogitsError
    cfg = _cfg()
    solo = DecodeEngine(cfg, EngineConfig(batch=1, max_len=12))
    toks = np.arange(4, dtype=np.int32)[None]
    out, _ = solo.generate({"tokens": toks}, gen=4, check_finite=True)
    assert out.shape == (1, 4)          # finite logits: no-op
    bad = F.FaultyStepFn(solo.decode_fn,
                         [F.NonFiniteLogits(step=0, slot=0)])
    solo.decode_fn = bad
    with pytest.raises(NonFiniteLogitsError, match="non-finite"):
        solo.generate({"tokens": toks}, gen=4, check_finite=True)


def test_call_with_retries_and_percentiles():
    calls = []

    def flaky(x):
        calls.append(x)
        if len(calls) < 3:
            raise RuntimeError("boom")
        return x + 1

    assert call_with_retries(
        flaky, 1, policy=RetryPolicy(max_retries=3, backoff_s=0.0)) == 2
    assert len(calls) == 3
    with pytest.raises(RuntimeError, match="always"):
        call_with_retries(
            (lambda: (_ for _ in ()).throw(RuntimeError("always"))),
            policy=RetryPolicy(max_retries=1, backoff_s=0.0))
    assert percentiles([]) == {}
    pct = percentiles(list(range(1, 101)))
    assert pct["p50"] == pytest.approx(50.5)
    assert pct["p99"] == pytest.approx(99.01)


def test_random_plan_is_seed_deterministic():
    a = F.random_plan(5, 64, slots=4, p_nonfinite=0.2, p_transient=0.2,
                      p_slow=0.1)
    b = F.random_plan(5, 64, slots=4, p_nonfinite=0.2, p_transient=0.2,
                      p_slow=0.1)
    assert len(a) > 0 and repr(a) == repr(b)
    assert repr(a) != repr(F.random_plan(6, 64, slots=4,
                                         p_nonfinite=0.2,
                                         p_transient=0.2, p_slow=0.1))


# ------------------------------------------------- allocator invariants


def test_allocator_double_free_and_foreign_free():
    al = PageAllocator(4)
    got = al.alloc(2)
    al.free([got[0]])
    with pytest.raises(ValueError, match="double free"):
        al.free([got[0]])               # already back in the pool
    with pytest.raises(ValueError, match="double free"):
        al.free([3])                    # never handed out
    with pytest.raises(ValueError, match="within one"):
        al.alloc(1)
        pages = al.alloc(1)
        al.free(pages + pages)
    al.check()


def test_allocator_invariants_seeded_sweep():
    """No-hypothesis fallback for the property test in
    tests/test_resilience_prop.py: seeded random alloc/free
    interleavings hold the owned/free pool partition after every op."""
    rng = np.random.default_rng(11)
    for n_pages in (1, 3, 8, 13):
        al = PageAllocator(n_pages)
        owned = []
        for _ in range(200):
            k = int(rng.integers(0, 5))
            if rng.random() < 0.5:
                if k > al.free_pages:
                    with pytest.raises(PagePoolExhausted):
                        al.alloc(k)
                else:
                    owned.extend(al.alloc(k))
            elif owned:
                take = owned[:min(k, len(owned))]
                owned = owned[len(take):]
                if take:
                    al.free(take)
            al.check()
            assert al.used_pages == len(owned)
        if owned:
            al.free(owned)
        al.check()
        assert al.free_pages == n_pages
