"""Property tests (hypothesis) for the fault-tolerant serving layer:
random alloc/free interleavings against the PageAllocator invariant,
and random admit/step/cancel sequences driving the Scheduler's
bookkeeping (growth, preemption, parking, rejection, retirement) on a
model-free fake engine.  Token-level correctness under faults is
pinned by tests/test_resilience.py on the real engine."""
import types

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine import (EngineConfig, Request, RequestStatus,  # noqa: E402
                          Scheduler)
from repro.engine import paged_cache as PC  # noqa: E402
from repro.engine.paged_cache import (PageAllocator,  # noqa: E402
                                      PagePoolExhausted)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 12),
       st.lists(st.tuples(st.booleans(), st.integers(0, 6)),
                max_size=40))
def test_allocator_invariants_under_random_ops(n_pages, ops):
    """Random alloc/free interleavings: the owned/free partition of
    the pool holds after every op, over-allocation always raises, and
    the pool drains back to fully free."""
    al = PageAllocator(n_pages)
    owned = []
    for is_alloc, k in ops:
        if is_alloc:
            if k > al.free_pages:
                with pytest.raises(PagePoolExhausted):
                    al.alloc(k)
            else:
                owned.extend(al.alloc(k))
        elif owned:
            take = owned[:min(k, len(owned))]
            owned = owned[len(take):]
            if take:
                al.free(take)
        al.check()
        assert al.used_pages == len(owned)
        assert len(set(owned)) == len(owned)
    if owned:
        al.free(owned)
    al.check()
    assert al.free_pages == n_pages


class _FakeEngine:
    """No-jax-model engine: real EngineConfig/paged-cache layout, but
    prefill/decode return zeros — fast enough to drive the *scheduler's
    bookkeeping* through hypothesis."""

    def __init__(self, batch=2, max_len=16, page_size=4, n_pages=6):
        self.cfg = types.SimpleNamespace(family="dense", mla=None,
                                         frontend_tokens=0)
        self.ecfg = EngineConfig(batch=batch, max_len=max_len,
                                 paged=True, page_size=page_size,
                                 n_pages=n_pages)
        self.page_size = page_size
        self.max_pages = PC.max_pages(max_len, page_size)
        self.n_pages = n_pages
        self.params = None
        L, KV, Dh, V = 1, 1, 1, 8
        self._pool = (L, n_pages, page_size, KV, Dh)
        self._V = V

    def init_paged_cache(self, enc_len=None):
        return {"k": jnp.zeros(self._pool), "v": jnp.zeros(self._pool)}

    def prefill_fn(self, params, batch):
        S = batch["tokens"].shape[1]
        kv = jnp.zeros((1, 1, S, 1, 1))
        return jnp.zeros((1, self._V)), (kv, kv)

    def decode_fn(self, params, dbatch):
        B = dbatch["token"].shape[0]
        return jnp.zeros((B, self._V)), dbatch["cache"]


_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("submit"), st.integers(1, 12),
                  st.integers(1, 6)),
        st.tuples(st.just("step"), st.just(0), st.just(0)),
        st.tuples(st.just("admit"), st.just(0), st.just(0)),
        st.tuples(st.just("cancel"), st.integers(0, 5), st.just(0))),
    min_size=1, max_size=14)


@settings(max_examples=10, deadline=None)
@given(_OPS, st.integers(0, 2))
def test_scheduler_invariants_under_random_sequences(ops, max_preempt):
    """Drive random submit/admit/step/cancel interleavings (growth,
    preemption, parking, rejection and retirement all fire from these)
    and assert after every transition: the allocator partition holds,
    active slots' pages are exactly the owned pages with no aliasing,
    and the drained stream leaves a full pool with every request
    terminal exactly once."""
    eng = _FakeEngine()
    sched = Scheduler(eng, max_preemptions=max_preempt)
    rng = np.random.default_rng(0)
    submitted = []

    def invariants():
        sched.allocator.check()
        pages = [p for s in sched.slots if s is not None
                 for p in s.pages]
        assert len(set(pages)) == len(pages), "page aliased across slots"
        assert len(pages) == sched.allocator.used_pages
        for s in sched.slots:
            if s is not None:
                assert s.req.status is RequestStatus.RUNNING

    for op, a, b in ops:
        if op == "submit":
            rid = len(submitted)
            submitted.append(rid)
            sched.submit(Request(
                rid=rid,
                tokens=rng.integers(0, 8, (a,)).astype(np.int32),
                gen=b))
        elif op == "step":
            sched.step()
        elif op == "admit":
            sched.admit()
        elif op == "cancel" and a < len(submitted):
            sched.cancel(a)
        invariants()
    out = sched.run()
    invariants()
    assert sched.allocator.free_pages == eng.n_pages
    assert set(out) == set(submitted)
    for rid in submitted:
        assert out[rid].status in {
            RequestStatus.FINISHED, RequestStatus.REJECTED,
            RequestStatus.CANCELLED, RequestStatus.TIMED_OUT,
            RequestStatus.FAILED}
