"""Property tests (hypothesis) for the fault-tolerant serving layer:
random alloc/free interleavings against the PageAllocator invariant,
random admit/step/cancel sequences driving the Scheduler's
bookkeeping (growth, preemption, parking, rejection, retirement) on a
model-free fake engine — with and without chunked prefill, where the
``pack_chunk`` token-budget rule must never exceed the budget, never
starve a decoding slot, and keep non-final chunks page-aligned — and
random insert/match/evict/decref interleavings against the
prefix-cache refcount partition (the trie plus outstanding holds
account for every ref, eviction never drops a held page).  Token-level
correctness under faults is pinned by tests/test_resilience.py;
prefix-cache token identity by tests/test_prefix_cache.py (which also
carries a deterministic mirror of the partition property for
hypothesis-less environments); chunked-prefill token identity by
tests/test_chunked.py.  The durable-serving property rides the same
fake engine: a crash injected at a RANDOM step of a RANDOM
submit/cancel stream, recovered via snapshot + journal replay
(``serve_with_recovery``), yields the same result map as the
crash-free run with the page pool fully drained (real-model
bit-identity lives in tests/test_snapshot.py)."""
import tempfile
import types

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine import (EngineConfig, PrefixCache, Request,  # noqa: E402
                          RequestStatus, Scheduler, faults)
from repro.engine import paged_cache as PC  # noqa: E402
from repro.engine.paged_cache import (PageAllocator,  # noqa: E402
                                      PagePoolExhausted)
from repro.engine.scheduler import pack_chunk  # noqa: E402
from repro.runtime.resilience import (RestartPolicy,  # noqa: E402
                                      serve_with_recovery)


@settings(max_examples=200, deadline=None)
@given(remaining=st.integers(1, 512), n_decode=st.integers(0, 64),
       budget=st.integers(1, 600), ct_pages=st.integers(1, 16),
       ps=st.sampled_from([1, 2, 4, 8]))
def test_pack_chunk_never_over_budget_never_starves(
        remaining, n_decode, budget, ct_pages, ps):
    """The token-budget packing rule, over its whole domain: the chunk
    never pushes the step past ``budget`` tokens, decoding slots are
    never starved (decodes alone filling the budget yields a zero
    chunk — never the other way around), non-final chunks end
    page-aligned, a chunk never overshoots the remaining prompt or
    ``chunk_tokens``, and whenever a whole page (or the whole
    remainder) fits beside the decodes the prefill makes progress."""
    ct = ct_pages * ps
    c = pack_chunk(remaining, n_decode, budget, ct, ps)
    assert 0 <= c <= min(remaining, ct)
    if c:
        assert n_decode + c <= budget   # never exceeds the budget
    if budget <= n_decode:
        assert c == 0                   # decode always wins the budget
    if 0 < c < remaining:
        assert c % ps == 0              # non-final chunks page-aligned
    room = min(budget - n_decode, ct)
    if room >= min(remaining, ps):
        assert c > 0                    # liveness: chunking advances


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 12),
       st.lists(st.tuples(st.booleans(), st.integers(0, 6)),
                max_size=40))
def test_allocator_invariants_under_random_ops(n_pages, ops):
    """Random alloc/free interleavings: the owned/free partition of
    the pool holds after every op, over-allocation always raises, and
    the pool drains back to fully free."""
    al = PageAllocator(n_pages)
    owned = []
    for is_alloc, k in ops:
        if is_alloc:
            if k > al.free_pages:
                with pytest.raises(PagePoolExhausted):
                    al.alloc(k)
            else:
                owned.extend(al.alloc(k))
        elif owned:
            take = owned[:min(k, len(owned))]
            owned = owned[len(take):]
            if take:
                al.free(take)
        al.check()
        assert al.used_pages == len(owned)
        assert len(set(owned)) == len(owned)
    if owned:
        al.free(owned)
    al.check()
    assert al.free_pages == n_pages


_PREFIX_OPS = st.lists(
    st.one_of(
        # (insert, token-seed, length)
        st.tuples(st.just("insert"), st.integers(0, 3),
                  st.integers(1, 14)),
        # (match, token-seed, length) — a hit takes a hold (incref)
        st.tuples(st.just("match"), st.integers(0, 3),
                  st.integers(1, 14)),
        st.tuples(st.just("release"), st.integers(0, 7), st.just(0)),
        st.tuples(st.just("evict"), st.integers(1, 4), st.just(0))),
    max_size=50)


def _toks(seed: int, length: int) -> np.ndarray:
    """Deterministic token stream per seed: overlapping prefixes across
    seeds (all start from the same base) so matches actually hit."""
    base = np.arange(length, dtype=np.int32)
    return base + (seed // 2)   # seeds 0/1, 2/3 share streams pairwise


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 16), _PREFIX_OPS)
def test_prefix_refcount_partition_under_random_ops(n_pages, ops):
    """Random insert / match+hold / release / evict interleavings: the
    refcount of every owned page equals (trie nodes owning it) +
    (outstanding match holds on it), eviction never frees a page a hold
    still pins, and clear() drains the pool completely."""
    ps = 4
    al = PageAllocator(n_pages)
    pc = PrefixCache(ps, al)
    holds = []

    def partition():
        counts = {}
        stack = list(pc._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            counts[nd.page] = counts.get(nd.page, 0) + 1
        for pages in holds:
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        assert set(counts) == {
            p for p in range(n_pages) if al.refcount(p) > 0}
        for p, want in counts.items():
            assert al.refcount(p) == want, f"page {p}"
        al.check()
        pc.check()

    for op, a, b in ops:
        if op == "insert":
            # retiring-slot idiom: alloc whole pages, insert, drop the
            # slot refs (trie keeps what it indexed, dupes free)
            n_whole = b // ps
            if n_whole <= al.free_pages:
                pages = al.alloc(n_whole)
                pc.insert(_toks(a, b), pages)
                if pages:
                    al.decref(pages)
        elif op == "match":
            pages = pc.match(_toks(a, b))
            if pages:
                al.incref(pages)
                holds.append(pages)
        elif op == "release" and holds:
            al.decref(holds.pop(a % len(holds)))
        elif op == "evict":
            held = {p for hold in holds for p in hold}
            pc.evict(a)
            for p in held:
                assert al.refcount(p) >= 1, "evicted a held page"
        partition()
    for pages in holds:
        al.decref(pages)
    pc.clear()
    al.check()
    assert al.free_pages == n_pages


class _FakeEngine:
    """No-jax-model engine: real EngineConfig/paged-cache layout, but
    prefill/decode return zeros — fast enough to drive the *scheduler's
    bookkeeping* through hypothesis."""

    def __init__(self, batch=2, max_len=16, page_size=4, n_pages=6):
        self.cfg = types.SimpleNamespace(family="dense", mla=None,
                                         frontend_tokens=0)
        self.ecfg = EngineConfig(batch=batch, max_len=max_len,
                                 paged=True, page_size=page_size,
                                 n_pages=n_pages)
        self.page_size = page_size
        self.max_pages = PC.max_pages(max_len, page_size)
        self.n_pages = n_pages
        self.params = None
        L, KV, Dh, V = 1, 1, 1, 8
        self._pool = (L, n_pages, page_size, KV, Dh)
        self._V = V

    def init_paged_cache(self, enc_len=None):
        return {"k": jnp.zeros(self._pool), "v": jnp.zeros(self._pool)}

    def prefill_fn(self, params, batch):
        S = batch["tokens"].shape[1]
        kv = jnp.zeros((1, 1, S, 1, 1))
        return jnp.zeros((1, self._V)), (kv, kv)

    def decode_fn(self, params, dbatch):
        B = dbatch["token"].shape[0]
        return jnp.zeros((B, self._V)), dbatch["cache"]

    def suffix_prefill_fn(self, params, batch):
        # suffix-only prefill: same zeros contract as prefill_fn, the
        # matched prefix rides along only as already-resident pages
        S = batch["tokens"].shape[1]
        kv = jnp.zeros((1, 1, S, 1, 1))
        return jnp.zeros((1, self._V)), (kv, kv)

    def mixed_fn(self, params, batch):
        # unified mixed step: (decode logits, chunk logits, cache) —
        # zeros keep the scheduler's chunk bookkeeping fully exercised
        B = batch["token"].shape[0]
        return (jnp.zeros((B, self._V)), jnp.zeros((1, self._V)),
                batch["cache"])


_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("submit"), st.integers(1, 12),
                  st.integers(1, 6)),
        st.tuples(st.just("step"), st.just(0), st.just(0)),
        st.tuples(st.just("admit"), st.just(0), st.just(0)),
        st.tuples(st.just("cancel"), st.integers(0, 5), st.just(0))),
    min_size=1, max_size=14)


@settings(max_examples=10, deadline=None)
@given(_OPS, st.integers(0, 2))
def test_scheduler_invariants_under_random_sequences(ops, max_preempt):
    """Drive random submit/admit/step/cancel interleavings (growth,
    preemption, parking, rejection and retirement all fire from these)
    and assert after every transition: the allocator partition holds,
    active slots' pages are exactly the owned pages with no aliasing,
    and the drained stream leaves a full pool with every request
    terminal exactly once."""
    eng = _FakeEngine()
    sched = Scheduler(eng, max_preemptions=max_preempt)
    rng = np.random.default_rng(0)
    submitted = []

    def invariants():
        sched.allocator.check()
        pages = [p for s in sched.slots if s is not None
                 for p in s.pages]
        assert len(set(pages)) == len(pages), "page aliased across slots"
        assert len(pages) == sched.allocator.used_pages
        for s in sched.slots:
            if s is not None:
                assert s.req.status is RequestStatus.RUNNING

    for op, a, b in ops:
        if op == "submit":
            rid = len(submitted)
            submitted.append(rid)
            sched.submit(Request(
                rid=rid,
                tokens=rng.integers(0, 8, (a,)).astype(np.int32),
                gen=b))
        elif op == "step":
            sched.step()
        elif op == "admit":
            sched.admit()
        elif op == "cancel" and a < len(submitted):
            sched.cancel(a)
        invariants()
    out = sched.run()
    invariants()
    assert sched.allocator.free_pages == eng.n_pages
    assert set(out) == set(submitted)
    for rid in submitted:
        assert out[rid].status in {
            RequestStatus.FINISHED, RequestStatus.REJECTED,
            RequestStatus.CANCELLED, RequestStatus.TIMED_OUT,
            RequestStatus.FAILED}


@settings(max_examples=10, deadline=None)
@given(_OPS, st.integers(0, 2))
def test_scheduler_chunked_invariants_under_random_sequences(
        ops, max_preempt):
    """The scheduler property with chunked prefill ON: active slots
    are RUNNING or PREFILLING, a PREFILLING slot's completed prefix is
    always whole pages (``prefilled`` page-aligned) and tracked in the
    chunking queue, pages are never aliased across slots OR the queued
    preempted slots that kept their completed pages, and — the packer's
    no-starvation guarantee surfaced at the scheduler level — every
    slot that enters a step RUNNING and leaves it RUNNING emits exactly
    one token, no matter what chunks rode along."""
    eng = _FakeEngine()
    sched = Scheduler(eng, max_preemptions=max_preempt,
                      chunked_prefill=True, chunk_tokens=4)
    rng = np.random.default_rng(0)
    submitted = []

    def invariants():
        sched.allocator.check()
        pages = [p for s in sched.slots if s is not None
                 for p in s.pages]
        for q in (sched.pending, sched.parked):
            for item in q:
                pages.extend(getattr(item, "pages", []))
        assert len(set(pages)) == len(pages), "page aliased"
        assert len(pages) == sched.allocator.used_pages
        for sid, s in enumerate(sched.slots):
            if s is None:
                assert sid not in sched._prefilling
                continue
            assert s.req.status in (RequestStatus.RUNNING,
                                    RequestStatus.PREFILLING)
            if s.req.status is RequestStatus.PREFILLING:
                assert sid in sched._prefilling
                assert s.prefilled % eng.page_size == 0
                assert s.prefilled < len(s.req.tokens)
            else:
                assert sid not in sched._prefilling

    for op, a, b in ops:
        if op == "submit":
            rid = len(submitted)
            submitted.append(rid)
            sched.submit(Request(
                rid=rid,
                tokens=rng.integers(0, 8, (a,)).astype(np.int32),
                gen=b))
        elif op == "step":
            running = {sid: (s.req.rid, len(s.out))
                       for sid, s in enumerate(sched.slots)
                       if s is not None
                       and s.req.status is RequestStatus.RUNNING}
            sched.step()
            for sid, (rid, n0) in running.items():
                s = sched.slots[sid]
                if (s is not None and s.req.rid == rid
                        and s.req.status is RequestStatus.RUNNING):
                    assert len(s.out) == n0 + 1, \
                        f"slot {sid} starved by the chunk"
        elif op == "admit":
            sched.admit()
        elif op == "cancel" and a < len(submitted):
            sched.cancel(a)
        invariants()
    out = sched.run()
    invariants()
    assert sched.allocator.free_pages == eng.n_pages
    assert set(out) == set(submitted)
    for rid in submitted:
        assert out[rid].status in {
            RequestStatus.FINISHED, RequestStatus.REJECTED,
            RequestStatus.CANCELLED, RequestStatus.TIMED_OUT,
            RequestStatus.FAILED}


@settings(max_examples=10, deadline=None)
@given(_OPS, st.integers(0, 2))
def test_scheduler_prefix_cache_invariants_under_random_sequences(
        ops, max_preempt):
    """The scheduler property with the prefix cache ON: the strict
    'no page aliased across slots' invariant is deliberately relaxed to
    the refcount partition — every owned page's refcount equals the
    slot rows holding it plus the trie nodes owning it — while
    eviction, preemption, growth and retirement interleave at random
    (prompts are drawn from a 2-token alphabet so cross-request prefix
    hits actually occur).  The drained pool holds exactly the trie's
    pages; clear() returns the rest."""
    eng = _FakeEngine()
    sched = Scheduler(eng, max_preemptions=max_preempt,
                      prefix_cache=True)
    rng = np.random.default_rng(0)
    submitted = []

    def invariants():
        sched.allocator.check()
        sched.prefix.check()
        counts = {}
        for s in sched.slots:
            if s is not None:
                assert s.req.status is RequestStatus.RUNNING
                for p in s.pages:
                    counts[p] = counts.get(p, 0) + 1
        stack = list(sched.prefix._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            counts[nd.page] = counts.get(nd.page, 0) + 1
        assert len(counts) == sched.allocator.used_pages
        for p, want in counts.items():
            assert sched.allocator.refcount(p) == want, f"page {p}"

    for op, a, b in ops:
        if op == "submit":
            rid = len(submitted)
            submitted.append(rid)
            sched.submit(Request(
                rid=rid,
                tokens=rng.integers(0, 2, (a,)).astype(np.int32),
                gen=b))
        elif op == "step":
            sched.step()
        elif op == "admit":
            sched.admit()
        elif op == "cancel" and a < len(submitted):
            sched.cancel(a)
        invariants()
    out = sched.run()
    invariants()
    assert sched.allocator.free_pages == \
        eng.n_pages - sched.prefix.cached_pages
    sched.prefix.clear()
    assert sched.allocator.free_pages == eng.n_pages
    assert set(out) == set(submitted)


_WORKLOAD = st.lists(
    st.one_of(
        # (submit, prompt_len, gen)
        st.tuples(st.just("submit"), st.integers(1, 10),
                  st.integers(1, 5)),
        # (cancel, submitted-index, _)
        st.tuples(st.just("cancel"), st.integers(0, 5), st.just(0))),
    min_size=1, max_size=8)


@settings(max_examples=10, deadline=None)
@given(_WORKLOAD, st.integers(1, 8), st.sampled_from([0, 2]))
def test_crash_recovery_result_map_identical(ops, crash_step, every):
    """Crash at a RANDOM step of a RANDOM submit/cancel stream,
    recover from the latest snapshot (cadence 0 = journal-only) plus
    the journal, and the final result map — every rid's tokens and
    terminal status — is identical to the crash-free run's, with the
    allocator partition intact and the pool fully drained.  (When the
    stream drains before ``crash_step`` decode calls the crash never
    fires and recovery is vacuous — hypothesis varies both sides.)"""

    def apply_ops(sched):
        rng = np.random.default_rng(0)
        submitted = []
        for op, a, b in ops:
            if op == "submit":
                rid = len(submitted)
                submitted.append(rid)
                sched.submit(Request(
                    rid=rid,
                    tokens=rng.integers(0, 8, (a,)).astype(np.int32),
                    gen=b))
            elif a < len(submitted):
                sched.cancel(a)

    ref = Scheduler(_FakeEngine())
    apply_ops(ref)
    want = ref.run()

    def on_start(sched, fresh):
        if fresh:       # the crash hits only the pre-recovery process
            faults.inject(sched, decode_faults=[
                faults.CrashFault(step=crash_step)])

    eng = _FakeEngine()
    with tempfile.TemporaryDirectory() as d:
        sched = serve_with_recovery(
            eng, d, apply_ops, snapshot_every=every,
            policy=RestartPolicy(max_restarts=3, backoff_s=0.0),
            on_start=on_start)
    assert set(sched.finished) == set(want)
    for rid, res in want.items():
        got = sched.finished[rid]
        assert got.status is res.status, f"req {rid}"
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(res),
                                      err_msg=f"req {rid}")
    sched.allocator.check()
    assert sched.allocator.free_pages == eng.n_pages
