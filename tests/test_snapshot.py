"""Crash-safe serving tests: the engine snapshot + write-ahead journal
+ replay recovery stack.  The headline property — a crash at an
arbitrary step, recovered from the latest snapshot plus the journal
suffix, produces the SAME greedy token streams, statuses and page
accounting as the crash-free run — is pinned bit-identically across
gqa/mla x bf16/int8 pools x prefix-cache x chunked-prefill.  Around it:
a mid-stream snapshot/restore roundtrip (free-list ORDER included), a
crash that beats the first snapshot cadence (journal-only recovery),
verbatim terminal recovery with zero recompute, torn-tail tolerance vs
mid-file corruption in the journal reader, geometry/version rejection
on restore, and async snapshot failures surfacing at teardown."""
import os
import types

import numpy as np
import pytest

from repro.common.config import MLAConfig, ModelConfig
from repro.engine import (DecodeEngine, EngineConfig, EngineSnapshotter,
                          Request, RequestJournal, Scheduler, faults,
                          read_events, replay, restore, snapshot)
from repro.runtime.resilience import RestartPolicy, serve_with_recovery

PS = 4          # page_size used throughout
CT = 8          # chunk_tokens (2 pages) used throughout


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


_MLA = MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                 nope_head_dim=16, v_head_dim=16)


def _mla_cfg():
    return _cfg(mla=_MLA)


# engines are the expensive part (param init + jit); the matrix only
# needs one per model family x kv dtype — prefix/chunked are Scheduler
# knobs layered on top
_ENGINES = {}


def _engine(make_cfg, kv_dtype):
    key = (make_cfg.__name__, kv_dtype)
    if key not in _ENGINES:
        _ENGINES[key] = DecodeEngine(make_cfg(), EngineConfig(
            batch=2, max_len=32, paged=True, page_size=PS, n_pages=24,
            chunked_prefill=True, chunk_tokens=CT, kv_dtype=kv_dtype))
    return _ENGINES[key]


# the workload every cell runs: two prompts sharing a 2-page system
# prefix (so prefix-cache cells actually hit), one long prompt (so
# chunked cells actually chunk), queueing turnover on a batch of 2
_SEED = 0


def _requests(vocab):
    rng = np.random.default_rng(_SEED)
    sys_p = rng.integers(2, vocab, (2 * PS,)).astype(np.int32)
    t0 = rng.integers(2, vocab, (4,)).astype(np.int32)
    t1 = rng.integers(2, vocab, (2,)).astype(np.int32)
    long_p = rng.integers(2, vocab, (18,)).astype(np.int32)
    specs = [(np.concatenate([sys_p, t0]), 6),
             (np.concatenate([sys_p, t1]), 5),
             (long_p, 6)]
    return [Request(rid=i, tokens=p, gen=g, seed=i)
            for i, (p, g) in enumerate(specs)]


def _assert_same_results(got, want):
    assert set(got) == set(want)
    for rid, res in want.items():
        assert got[rid].status is res.status, f"req {rid}"
        np.testing.assert_array_equal(np.asarray(got[rid]),
                                      np.asarray(res),
                                      err_msg=f"req {rid}")


# ------------------------------------------------- crash + recover matrix


# the int8 cells pin greedy identity empirically at this scale/seed —
# recovery re-indexes a finished slot's prefix at snapshot-time length,
# so a post-crash prefix hit can read quantized pages where the
# crash-free run read a longer cached span (same near-tie caveat the
# prefix-cache int8 tests carry)
@pytest.mark.parametrize("make_cfg", [_cfg, _mla_cfg], ids=["gqa", "mla"])
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("prefix", [False, True],
                         ids=["no-prefix", "prefix"])
@pytest.mark.parametrize("chunked", [False, True],
                         ids=["no-chunk", "chunk"])
def test_crash_recover_bit_identical(make_cfg, kv_dtype, prefix,
                                     chunked, tmp_path):
    eng = _engine(make_cfg, kv_dtype)
    kw = dict(prefix_cache=prefix, chunked_prefill=chunked)

    ref = Scheduler(eng, **kw)
    for r in _requests(eng.cfg.vocab):
        ref.submit(r)
    want = ref.run()
    assert all(res.ok for res in want.values())

    starts, proxies = [], []

    def on_start(sched, fresh):
        starts.append(fresh)
        if fresh:        # the crash hits only the pre-recovery process
            proxies.append(faults.inject(sched, decode_faults=[
                faults.CrashFault(step=5)]))

    def submit(sched):
        for r in _requests(eng.cfg.vocab):
            sched.submit(r)

    sched = serve_with_recovery(
        eng, str(tmp_path), submit, snapshot_every=2,
        policy=RestartPolicy(max_restarts=3, backoff_s=0.0),
        on_start=on_start, sched_kwargs=kw)

    # the crash fired, the restart loop recovered (fresh, then not)
    assert starts[0] is True and False in starts[1:]
    assert sum(p.decode_fn.injected
               + (p.mixed_fn.injected if p.mixed_fn else 0)
               for p in proxies) >= 1
    assert sched.snapshotter.saved >= 1

    _assert_same_results(sched.finished, want)
    sched.allocator.check()
    cached = sched.prefix.cached_pages if sched.prefix is not None else 0
    assert sched.allocator.free_pages == eng.n_pages - cached
    if sched.prefix is not None:
        sched.prefix.check()


# ------------------------------------------------- snapshot/restore unit


def test_snapshot_restore_roundtrip_mid_stream(tmp_path):
    """Cut a snapshot mid-drain; the restored scheduler carries the
    same allocator partition (free-list ORDER included), block tables
    and knobs, and both finish with identical results."""
    eng = _engine(_cfg, "bf16")
    a = Scheduler(eng, prefix_cache=True, chunked_prefill=True)
    for r in _requests(eng.cfg.vocab):
        a.submit(r)
    a.admit()
    for _ in range(3):
        a.step()
    step = snapshot(a, str(tmp_path))
    assert step == a.stats["steps"]

    b = restore(str(tmp_path), eng)
    assert b.prefix is not None and b.chunked   # knobs from the snapshot
    assert b.stats["steps"] == a.stats["steps"]
    assert b.allocator.to_state() == a.allocator.to_state()
    np.testing.assert_array_equal(b.table, a.table)
    np.testing.assert_array_equal(b.lens, a.lens)
    assert [s and s.req.rid for s in b.slots] == \
        [s and s.req.rid for s in a.slots]

    a.run()
    b.run()
    _assert_same_results(b.finished, a.finished)
    b.allocator.check()
    b.prefix.check()


def test_crash_before_first_snapshot_recovers_from_journal(tmp_path):
    """snapshot_every=0: journal-only durability.  The crash beats any
    snapshot, recovery replays the whole journal into a fresh
    scheduler, and the streams still match the crash-free run."""
    eng = _engine(_cfg, "bf16")
    ref = Scheduler(eng)
    for r in _requests(eng.cfg.vocab):
        ref.submit(r)
    want = ref.run()

    def on_start(sched, fresh):
        if fresh:
            faults.inject(sched, decode_faults=[
                faults.CrashFault(step=3)])

    def submit(sched):
        for r in _requests(eng.cfg.vocab):
            sched.submit(r)

    sched = serve_with_recovery(
        eng, str(tmp_path), submit, snapshot_every=0,
        policy=RestartPolicy(max_restarts=3, backoff_s=0.0),
        on_start=on_start)
    assert sched.snapshotter.saved == 0
    assert sched.snapshotter.latest_step() is None
    _assert_same_results(sched.finished, want)
    assert sched.allocator.free_pages == eng.n_pages


def test_replay_recovers_terminals_verbatim_without_recompute(tmp_path):
    """A journal whose every submit already went terminal replays into
    a fresh scheduler as pure bookkeeping: each submit re-queues, each
    terminal drops the queued residue and records the result VERBATIM
    — zero decode steps run, zero pages stay held."""
    eng = _engine(_cfg, "bf16")
    jpath = str(tmp_path / "journal.jsonl")
    j = RequestJournal(jpath)
    a = Scheduler(eng, journal=j)
    for r in _requests(eng.cfg.vocab):
        a.submit(r)
    want = a.run()
    j.close()

    events = read_events(jpath)
    assert [e["ev"] for e in events].count("terminal") == len(want)

    b = Scheduler(eng)
    stats = replay(b, events)
    assert stats["requeued"] == len(want)       # submits re-queue...
    assert stats["recovered"] == len(want)      # ...terminals drop them
    assert b.stats["steps"] == 0                # nothing recomputed
    assert not b.pending and b.allocator.free_pages == eng.n_pages
    _assert_same_results(b.finished, want)
    for rid, res in want.items():
        assert b.finished[rid].latency_s == res.latency_s
        assert b.finished[rid].token_times == res.token_times

    # idempotence: replaying the same log again is all no-ops
    again = replay(b, events)
    assert again == {"recovered": 0, "requeued": 0, "cancelled": 0,
                     "noop": len(events)}


def test_journal_cancel_replays_against_live_request(tmp_path):
    """A journaled cancel with no terminal yet (the crash landed
    between the cancel append and its effect) re-applies on replay."""
    eng = _engine(_cfg, "bf16")
    jpath = str(tmp_path / "journal.jsonl")
    j = RequestJournal(jpath)
    j.submit(_requests(eng.cfg.vocab)[0])
    j.cancel(0)
    j.close()

    sched = Scheduler(eng)
    stats = replay(sched, read_events(jpath))
    assert stats == {"recovered": 0, "requeued": 1, "cancelled": 1,
                     "noop": 0}
    assert not sched.finished[0].ok
    assert sched.allocator.free_pages == eng.n_pages


# ------------------------------------------------- journal reader edges


def test_journal_torn_tail_tolerated_mid_corruption_raises(tmp_path):
    p = str(tmp_path / "j.jsonl")
    j = RequestJournal(p)
    j.submit(Request(rid=0, tokens=np.arange(2, 5, dtype=np.int32),
                     gen=2))
    j.cancel(0)
    j.close()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"ev": "subm')                 # died mid-append
    assert [e["ev"] for e in read_events(p)] == ["submit", "cancel"]

    with open(p, "a", encoding="utf-8") as f:   # torn line now MID-file
        f.write('\n{"ev": "cancel", "rid": 0}\n')
    with pytest.raises(ValueError, match="corrupt"):
        read_events(p)

    assert read_events(str(tmp_path / "missing.jsonl")) == []


def test_journal_reopen_repairs_torn_tail(tmp_path):
    """Double-crash: the writer dies mid-append, the recovered process
    reopens the SAME journal and keeps appending, then crashes again.
    The reopen must truncate the torn fragment so the new appends land
    on a clean line boundary — otherwise the first post-recovery event
    is glued onto the fragment, and the second recovery finds corrupt
    JSON mid-file and fails permanently."""
    from repro.engine import RequestResult, RequestStatus

    p = str(tmp_path / "j.jsonl")
    j = RequestJournal(p)
    j.submit(Request(rid=0, tokens=np.arange(2, 5, dtype=np.int32),
                     gen=2))
    j.close()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"ev": "subm')                 # crash #1, mid-append

    j2 = RequestJournal(p)                      # recovered process
    j2.cancel(0)
    j2.terminal(0, RequestResult(np.arange(3, dtype=np.int32),
                                 RequestStatus.CANCELLED,
                                 error="cancelled mid-flight"))
    j2.close()

    # crash #2: replay must parse every acknowledged event cleanly —
    # the torn fragment is gone, nothing was glued onto it
    evs = read_events(p)
    assert [e["ev"] for e in evs] == ["submit", "cancel", "terminal"]
    assert evs[2]["status"] == RequestStatus.CANCELLED.value

    # repair is append-only-safe: a clean journal reopens untouched
    before = open(p, encoding="utf-8").read()
    RequestJournal(p).close()
    assert open(p, encoding="utf-8").read() == before


def test_replay_cancel_intent_before_terminal_is_noop(tmp_path):
    """The scheduler journals a cancel as INTENT before appending the
    authoritative terminal.  On replay the cancel must not re-run
    against the restored (snapshot-time) partial state — that would
    synthesize a fresh CANCELLED result with fewer tokens and wrong
    latency, shadowing the verbatim terminal that follows."""
    eng = _engine(_cfg, "bf16")
    jpath = str(tmp_path / "journal.jsonl")
    j = RequestJournal(jpath)
    a = Scheduler(eng, journal=j)
    reqs = _requests(eng.cfg.vocab)
    for r in reqs:
        a.submit(r)
    a.admit()
    for _ in range(2):
        a.step()
    assert a.cancel(reqs[0].rid)    # journal order: cancel, terminal
    want = a.run()
    j.close()

    events = read_events(jpath)
    kinds = [(e["ev"], e["rid"]) for e in events]
    assert kinds.index(("cancel", 0)) < kinds.index(("terminal", 0))

    b = Scheduler(eng)
    stats = replay(b, events)
    assert stats["cancelled"] == 0  # intent superseded by its terminal
    _assert_same_results(b.finished, want)
    assert b.finished[0].latency_s == want[0].latency_s
    assert b.finished[0].token_times == want[0].token_times
    assert b.allocator.free_pages == eng.n_pages


# ------------------------------------------------- restore validation


def test_restore_rejects_geometry_mismatch(tmp_path):
    eng = _engine(_cfg, "bf16")
    snapshot(Scheduler(eng), str(tmp_path))
    fake = types.SimpleNamespace(
        ecfg=types.SimpleNamespace(batch=2, max_len=32, kv_dtype="bf16"),
        page_size=PS, n_pages=eng.n_pages + 8,
        cfg=types.SimpleNamespace(family="dense"))
    with pytest.raises(ValueError, match="geometry"):
        restore(str(tmp_path), fake)


def test_restore_rejects_non_snapshot_checkpoint(tmp_path):
    """A training checkpoint (no 'host' leaf) is not an engine
    snapshot and must be refused, not half-restored."""
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(str(tmp_path))
    store.save(0, {"w": np.zeros((3,), np.float32)})
    eng = _engine(_cfg, "bf16")
    with pytest.raises(ValueError, match="not an engine snapshot"):
        restore(store, eng, step=0)


def test_restore_without_snapshot_is_fresh(tmp_path):
    eng = _engine(_cfg, "bf16")
    sched = restore(str(tmp_path / "empty"), eng)
    assert sched.stats["steps"] == 0 and not sched.finished
    assert sched.allocator.free_pages == eng.n_pages


# ------------------------------------------------- async cadence failure


def test_async_snapshot_failure_surfaces(tmp_path, monkeypatch):
    """A dying disk under the background snapshot writer must surface
    in the serving loop (next cadence or drain-end wait), never be
    silently dropped."""
    eng = _engine(_cfg, "bf16")
    snap = EngineSnapshotter(str(tmp_path), every=1)

    def boom(step, host):
        raise OSError("disk died")

    monkeypatch.setattr(snap.store, "_write", boom)
    sched = Scheduler(eng, snapshotter=snap)
    for r in _requests(eng.cfg.vocab):
        sched.submit(r)
    with pytest.raises(OSError, match="disk died"):
        sched.run()
    # teardown after the failure is idempotent, not a second raise
    snap.close()
