"""End-to-end trainer: loss decreases; checkpoint-resume is exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw


def _setup(steps=30):
    cfg = reduced(get_config("tinyllama-1.1b"))
    opt_cfg = adamw.OptConfig(lr=1e-3, warmup_steps=5, total_steps=steps)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=4))
    step = jax.jit(build_train_step(cfg, opt_cfg))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(opt_cfg, params)
    return cfg, data, step, params, opt


def _np_batch(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases():
    cfg, data, step, params, opt = _setup(steps=50)
    losses = []
    for s in range(50):
        params, opt, m = step(params, opt, _np_batch(data.batch(s)))
        losses.append(float(m["loss"]))
    head = np.mean(losses[:5])
    tail = np.mean(losses[-5:])
    assert tail < head - 0.3, (head, tail)
    assert all(np.isfinite(l) for l in losses)


def test_microbatch_accumulation_close_to_full_batch():
    """nm=4 grad accumulation ~= single big batch (same data)."""
    cfg = reduced(get_config("tinyllama-1.1b"))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8))
    batch = _np_batch(data.batch(0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)

    p1, _, m1 = jax.jit(build_train_step(cfg, opt_cfg))(
        params, adamw.init(opt_cfg, params), batch)
    cfg4 = cfg.replace(n_microbatches=4)
    p4, _, m4 = jax.jit(build_train_step(cfg4, opt_cfg))(
        params, adamw.init(opt_cfg, params), batch)
    # same total gradient (mean over microbatches == full batch mean)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-5, max(jax.tree.leaves(d))


def test_microbatch_metrics_average_all_microbatches():
    """Regression: logged metrics under nm>1 must equal the nm=1
    metrics on the same batch (the pre-fix code took metrics[-1], so
    every aux metric reflected only the FINAL microbatch)."""
    cfg = reduced(get_config("tinyllama-1.1b"))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8))
    batch = _np_batch(data.batch(0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)

    _, _, m1 = jax.jit(build_train_step(cfg, opt_cfg))(
        params, adamw.init(opt_cfg, params), batch)
    cfg4 = cfg.replace(n_microbatches=4)
    _, _, m4 = jax.jit(build_train_step(cfg4, opt_cfg))(
        params, adamw.init(opt_cfg, params), batch)
    assert set(m1) == set(m4)
    for k in ("ce", "z_loss", "loss"):
        np.testing.assert_allclose(float(m4[k]), float(m1[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    # and the average is NOT just the last microbatch's value: the
    # last microbatch alone gives a measurably different ce here
    (_, mb_metrics), _ = jax.value_and_grad(
        lambda p: lm.train_loss(
            p, jax.tree.map(lambda x: x[6:], batch), cfg), has_aux=True
    )(params)
    assert abs(float(mb_metrics["ce"]) - float(m1["ce"])) > 1e-4


def test_checkpoint_resume_exact(tmp_path):
    """Stop at step 10, resume, reach step 20 with bit-identical params
    vs an uninterrupted run (stateless data pipeline + full state
    checkpointing)."""
    cfg, data, step, params, opt = _setup()
    store = CheckpointStore(str(tmp_path))

    # uninterrupted
    p_ref, o_ref = params, opt
    for s in range(20):
        p_ref, o_ref, _ = step(p_ref, o_ref, _np_batch(data.batch(s)))

    # interrupted at 10
    p, o = params, opt
    for s in range(10):
        p, o, _ = step(p, o, _np_batch(data.batch(s)))
    store.save(10, {"params": p, "opt": o})

    tpl = {"params": jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), p),
        "opt": jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), o)}
    restored = store.restore(10, tpl)
    p2, o2 = restored["params"], restored["opt"]
    for s in range(10, 20):
        p2, o2, _ = step(p2, o2, _np_batch(data.batch(s)))

    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         p_ref, p2)
    assert max(jax.tree.leaves(diffs)) == 0.0, diffs
